"""Multi-tenant serving: the grouped gsB-folded compose, request routing
through the adapter-state LRU, and the acceptance contract — a mixed
N≥3-adapter batch decodes in ONE step, bitwise-equal (fp32) to serving
each tenant sequentially with its own precomputed state, with zero
``dora_wnorm``-tagged ops in the grouped decode jaxpr.

Multi-device parity runs in a subprocess (same pattern as
``test_compose_spmd.py``): the forced-device-count XLA flag must be set
before jax initializes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AdapterCacheMiss, AdapterStateCache, DoRAConfig,
                        dora_linear, dora_linear_grouped, init_dora_params,
                        precompute_adapter_state, stack_adapter_states)
from repro.launch.serve import MultiTenantServer, Request, generate
from repro.launch.steps import StepConfig, make_decode_step
from repro.launch.train import build_state

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
ARCH = "qwen2-7b"


def _tenants(W, n, *, fold_gsb=True):
    key = jax.random.PRNGKey(7)
    states, raws = [], []
    for k in range(n):
        adp = init_dora_params(jax.random.fold_in(key, k), W, DCFG)
        adp["B"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 50 + k),
                                           adp["B"].shape)
        raws.append(adp)
        states.append(precompute_adapter_state(
            W, adp, DCFG, act_dtype=jnp.float32, fold_gsb=fold_gsb))
    return raws, states


class TestGroupedLinear:
    D_IN, D_OUT, K = 64, 96, 3

    def _xW(self, rows):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, rows + (self.D_IN,), jnp.float32)
        W = jax.random.normal(jax.random.fold_in(key, 1),
                              (self.D_OUT, self.D_IN))
        return x, W

    @pytest.mark.parametrize("seq", [1, 5])
    def test_grouped_bitwise_vs_homogeneous(self, seq):
        """Each ≥2-row group through the grouped path is BITWISE the
        homogeneous gsB fast path on the same rows — decode (S=1) and
        prefill (S>1) shapes."""
        x, W = self._xW((2 * self.K, seq))
        _, states = _tenants(W, self.K)
        stacked = stack_adapter_states(states, axis=0)
        groups = tuple((2 * k, 2) for k in range(self.K))
        yg = jax.jit(lambda x: dora_linear_grouped(
            x, W, stacked, DCFG, groups))(x)
        for k in range(self.K):
            sl = slice(2 * k, 2 * k + 2)
            yh = jax.jit(lambda xs, st=states[k]: dora_linear(
                xs, W, st, DCFG, training=False))(x[sl])
            np.testing.assert_array_equal(np.asarray(yh),
                                          np.asarray(yg[sl]),
                                          err_msg=f"tenant {k} seq {seq}")

    def test_uneven_groups_and_bias(self):
        x, W = self._xW((5, 1))
        _, states = _tenants(W, 2)
        stacked = stack_adapter_states(states, axis=0)
        bias = jax.random.normal(jax.random.PRNGKey(3), (self.D_OUT,))
        groups = ((0, 3), (3, 2))
        yg = dora_linear_grouped(x, W, stacked, DCFG, groups, bias=bias)
        for k, (s, n) in enumerate(groups):
            yh = dora_linear(x[s:s + n], W, states[k], DCFG, bias=bias,
                             training=False)
            np.testing.assert_allclose(np.asarray(yh),
                                       np.asarray(yg[s:s + n]),
                                       rtol=0, atol=0)

    def test_requires_folded_state(self):
        x, W = self._xW((4, 1))
        _, states = _tenants(W, 2, fold_gsb=False)
        stacked = stack_adapter_states(states, axis=0)
        with pytest.raises(ValueError, match="gsB"):
            dora_linear_grouped(x, W, stacked, DCFG, ((0, 2), (2, 2)))

    def test_serving_only(self):
        x, W = self._xW((4, 1))
        _, states = _tenants(W, 2)
        stacked = stack_adapter_states(states, axis=0)
        with pytest.raises(ValueError, match="serving-only"):
            dora_linear(x, W, stacked, DCFG, training=True,
                        tenant_groups=((0, 2), (2, 2)))

    def test_bad_groupings_rejected(self):
        x, W = self._xW((4, 1))
        _, states = _tenants(W, 2)
        stacked = stack_adapter_states(states, axis=0)
        for groups, match in [
            (((0, 2), (3, 1)), "contiguously"),     # gap
            (((0, 2), (2, 1)), "cover"),            # short
            (((0, 4),), "tenant groups but"),       # K mismatch
            ((), "at least one"),
        ]:
            with pytest.raises(ValueError, match=match):
                dora_linear_grouped(x, W, stacked, DCFG, groups)

    def test_stacked_weights_unsupported(self):
        key = jax.random.PRNGKey(2)
        W = jax.random.normal(key, (2, 96, 64))
        _, states = _tenants(W, 2)
        stacked = stack_adapter_states(states, axis=0)
        x = jax.random.normal(key, (4, 1, 64))
        with pytest.raises(NotImplementedError, match="stacked"):
            dora_linear_grouped(x, W, stacked, DCFG, ((0, 2), (2, 2)))


class TestGroupedModel:
    def _setup(self, n=3):
        mcfg = get_config(ARCH, smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, _, _ = build_state(mcfg, DCFG, 0)
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        for t in range(n):
            _, ad, _ = build_state(mcfg, DCFG, 10 + t)
            cache.register(f"t{t}", ad)
        return mcfg, scfg, params, cache

    def test_grouped_decode_jaxpr_has_zero_norm_work(self):
        """Acceptance: the grouped decode step (cache hit) contains no
        ``dora_wnorm``-tagged op — a mixed-adapter batch does zero
        factored-norm work per token."""
        mcfg, scfg, params, cache = self._setup()
        states = [cache.get_state(params, cache.current_handle(f"t{t}"))
                  for t in range(3)]
        stacked = stack_adapter_states(states, axis=1)
        groups = ((0, 2), (2, 2), (4, 2))
        from repro.models import init_cache
        dec_cache = init_cache(mcfg, 6, 8)
        decode = make_decode_step(mcfg, scfg, None, batch=6,
                                  tenant_groups=groups)
        jaxpr = str(jax.make_jaxpr(decode)(
            params, stacked, dec_cache,
            {"tokens": jnp.zeros((6, 1), jnp.int32)}))
        assert "dora_wnorm" not in jaxpr

    def test_mamba_arch_rejected(self):
        mcfg = get_config("falcon-mamba-7b", smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        from repro.models import forward, init_cache
        with pytest.raises(NotImplementedError, match="attention"):
            jax.eval_shape(
                lambda p, a: forward(
                    mcfg, p, a, DCFG, tokens=jnp.zeros((2, 1), jnp.int32),
                    cache=init_cache(mcfg, 2, 4), training=False,
                    tenant_groups=((0, 2),)),
                params, adapters)

    def test_forward_training_rejected(self):
        mcfg = get_config(ARCH, smoke=True)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        from repro.models import forward
        with pytest.raises(ValueError, match="serving-only"):
            forward(mcfg, params, adapters, DCFG,
                    tokens=jnp.zeros((2, 4), jnp.int32), training=True,
                    tenant_groups=((0, 2),))


class TestServer:
    P, G, ML = 6, 4, 12

    def _requests(self, cache, mcfg, tenants=3, rows=2, seed=0):
        rng = np.random.default_rng(seed)
        reqs = []
        for t in range(tenants):
            for _ in range(rows):
                reqs.append(Request(
                    rng.integers(0, mcfg.vocab_size, self.P,
                                 dtype=np.int32), f"t{t}"))
        # interleave tenants so the server's sort actually permutes
        order = rng.permutation(len(reqs))
        return [reqs[i] for i in order]

    def _setup(self, n=3, mesh=None):
        mcfg = get_config(ARCH, smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, _, _ = build_state(mcfg, DCFG, 0)
        cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
        for t in range(n):
            _, ad, _ = build_state(mcfg, DCFG, 10 + t)
            cache.register(f"t{t}", ad)
        server = MultiTenantServer(mcfg, scfg, params, cache=cache,
                                   mesh=mesh)
        return mcfg, scfg, params, cache, server

    def test_mixed_batch_bitwise_equals_sequential(self):
        """ACCEPTANCE: N=3 adapters in one batch — logits (every sampled
        step) and tokens bitwise-equal (fp32) to serving each tenant
        sequentially with its own precomputed state."""
        mcfg, scfg, params, cache, server = self._setup()
        reqs = self._requests(cache, mcfg)
        toks, logits = server.serve(reqs, gen_len=self.G, max_len=self.ML,
                                    return_logits=True)
        toks = np.asarray(toks)
        assert len(logits) == self.G
        for t in range(3):
            rows = [i for i, r in enumerate(reqs) if r.adapter == f"t{t}"]
            prompts = np.stack([np.asarray(reqs[i].prompt) for i in rows])
            st, sl = generate(mcfg, params, cache.current_handle(f"t{t}"),
                              scfg, prompts, gen_len=self.G,
                              max_len=self.ML, adapter_cache=cache,
                              return_logits=True)
            np.testing.assert_array_equal(np.asarray(st), toks[rows],
                                          err_msg=f"tokens t{t}")
            for s in range(self.G):
                np.testing.assert_array_equal(sl[s], logits[s][rows],
                                              err_msg=f"logits t{t} "
                                                      f"step {s}")

    def test_homogeneous_batch_keeps_single_tenant_path(self):
        """All-one-adapter batches route through today's single-tenant
        loop bitwise (no grouping, no stacked tree)."""
        mcfg, scfg, params, cache, server = self._setup(n=1)
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, mcfg.vocab_size, (4, self.P),
                               dtype=np.int32)
        reqs = [Request(p, "t0") for p in prompts]
        toks = np.asarray(server.serve(reqs, gen_len=self.G,
                                       max_len=self.ML))
        ref = np.asarray(generate(
            mcfg, params, cache.current_handle("t0"), scfg, prompts,
            gen_len=self.G, max_len=self.ML, adapter_cache=cache))
        np.testing.assert_array_equal(toks, ref)
        # the single-tenant path compiled with groups=None
        assert all(k[3] is None for k in server._steps)

    def test_allow_miss_false_rejects_cold_state(self):
        mcfg, scfg, params, cache, server = self._setup()
        reqs = self._requests(cache, mcfg)
        with pytest.raises(AdapterCacheMiss, match="allow_miss"):
            server.serve(reqs, gen_len=2, max_len=self.ML,
                         allow_miss=False)
        # warming every tenant makes the same call pass
        for t in range(3):
            cache.get_state(params, cache.current_handle(f"t{t}"))
        server.serve(reqs, gen_len=2, max_len=self.ML, allow_miss=False)

    def test_generate_rejects_stale_handle(self):
        """The satellite contract: a handle whose version is behind the
        registry is ALWAYS rejected with the key fields named — swapping
        adapters without re-precomputing can never serve stale logits."""
        mcfg, scfg, params, cache, _ = self._setup()
        rng = np.random.default_rng(2)
        prompts = rng.integers(0, mcfg.vocab_size, (2, self.P),
                               dtype=np.int32)
        h0 = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 42)
        cache.update("t0", ad_new)
        with pytest.raises(AdapterCacheMiss) as ei:
            generate(mcfg, params, h0, scfg, prompts, gen_len=2,
                     max_len=self.ML, adapter_cache=cache)
        msg = str(ei.value)
        assert "stale adapter handle" in msg
        for field in ("adapter_id='t0'", "version=0", "act_dtype",
                      "fold_gsb"):
            assert field in msg, (field, msg)

    def test_generate_handle_without_cache_rejected(self):
        mcfg, scfg, params, cache, _ = self._setup()
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, mcfg.vocab_size, (2, self.P),
                               dtype=np.int32)
        with pytest.raises(ValueError, match="adapter_cache"):
            generate(mcfg, params, cache.current_handle("t0"), scfg,
                     prompts, gen_len=2, max_len=self.ML)

    def test_cache_mesh_fingerprint_mismatch_rejected(self):
        """A cache keyed for one mesh must not serve another: the cached
        states would be re-laid-out every step. Both the server ctor and
        handle-resolving generate() refuse loudly."""
        from repro.launch.mesh import make_debug_mesh
        mcfg, scfg, params, cache, _ = self._setup()   # cache: mesh=None
        mesh = make_debug_mesh(1, 1)
        with pytest.raises(ValueError, match="keyed for sharding"):
            MultiTenantServer(mcfg, scfg, params, cache=cache, mesh=mesh)
        rng = np.random.default_rng(4)
        prompts = rng.integers(0, mcfg.vocab_size, (2, self.P),
                               dtype=np.int32)
        with pytest.raises(ValueError, match="keyed for sharding"):
            generate(mcfg, params, cache.current_handle("t0"), scfg,
                     prompts, gen_len=2, max_len=self.ML,
                     adapter_cache=cache, mesh=mesh)

    def test_step_cache_is_bounded(self):
        mcfg, scfg, params, cache, server = self._setup()
        server.max_cached_steps = 2
        rng = np.random.default_rng(5)
        for n in range(3):           # three distinct bucket signatures
            prompts = rng.integers(0, mcfg.vocab_size, (2, self.P),
                                   dtype=np.int32)
            reqs = [Request(p, "t0") for p in prompts]
            server.serve(reqs, gen_len=1, max_len=self.ML + n)
        assert len(server._steps) == 2

    def test_mixed_prompt_lengths_route_through_engine(self):
        """Mixed-length batches are ADMITTED (continuous-batching engine,
        per-row prefill) — the legacy length-bucket error survives only
        on the forced static path. Full oracle coverage lives in
        tests/test_engine.py."""
        mcfg, scfg, params, cache, server = self._setup()
        rng = np.random.default_rng(7)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, 6,
                                     dtype=np.int32), "t0"),
                Request(rng.integers(0, mcfg.vocab_size, 7,
                                     dtype=np.int32), "t1")]
        out = server.serve(reqs, gen_len=2, max_len=self.ML)
        assert isinstance(out, list)
        assert [len(o) for o in out] == [8, 9]
        with pytest.raises(ValueError, match="length bucket"):
            server.serve(reqs, gen_len=2, max_len=self.ML, static=True)


# ---------------------------------------------------------------------------
# Forced 2-device mesh (subprocess): grouped mixed batch vs sequential.
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_FORCE_TIER", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_MT_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import MultiTenantServer, Request, generate
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)     # batch sharded over the data axis
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    assert cache.sharding == (("data", 2), ("model", 1))
    for t in range(3):
        _, ad, _ = build_state(mcfg, DCFG, 10 + t)
        cache.register(f"t{t}", ad)
    server = MultiTenantServer(mcfg, scfg, params, cache=cache, mesh=mesh)

    P, G, ML = 6, 3, 10
    rng = np.random.default_rng(0)
    reqs = []
    for t in range(3):
        for _ in range(2):
            reqs.append(Request(rng.integers(0, mcfg.vocab_size, P,
                                             dtype=np.int32), f"t{t}"))
    toks, logits = server.serve(reqs, gen_len=G, max_len=ML,
                                return_logits=True)
    toks = np.asarray(toks)
    for t in range(3):
        rows = [i for i, r in enumerate(reqs) if r.adapter == f"t{t}"]
        prompts = np.stack([np.asarray(reqs[i].prompt) for i in rows])
        st, sl = generate(mcfg, params, cache.current_handle(f"t{t}"),
                          scfg, prompts, gen_len=G, max_len=ML,
                          adapter_cache=cache, mesh=mesh,
                          return_logits=True)
        assert np.array_equal(np.asarray(st), toks[rows]), f"tokens t{t}"
        for s in range(G):
            assert np.array_equal(sl[s], logits[s][rows]), (t, s)
    print("MT_SPMD_BITWISE_OK")
"""


@pytest.mark.slow
def test_multitenant_spmd_parity():
    """Acceptance on a forced 2-device CPU mesh: the grouped mixed batch
    (batch sharded over the data axis, per-tenant states precomputed and
    pinned through the mesh-aware cache) serves bitwise-identical fp32
    logits to per-tenant sequential serving under the same mesh."""
    out = _run_subprocess(_MT_SPMD, 2)
    assert "MT_SPMD_BITWISE_OK" in out, out
