"""SSM scan implementations: the traffic-optimal fused-chunk formulation
must match the associative-scan baseline (and a plain python recurrence)
bit-for-bit at fp32 tolerance, including padding tails and cache carry."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; pip install -r "
           "requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.mamba import _ssm_scan, _ssm_scan_fused

_F32 = jnp.float32


def _inputs(key, B, S, di, n):
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di), _F32))
    xi = jax.random.normal(ks[1], (B, S, di), _F32)
    Bm = jax.random.normal(ks[2], (B, S, n), _F32)
    Cm = jax.random.normal(ks[3], (B, S, n), _F32)
    A = -jnp.exp(jax.random.normal(ks[4], (di, n), _F32))
    h0 = jax.random.normal(jax.random.fold_in(key, 9), (B, di, n), _F32)
    return dt, xi, Bm, Cm, A, h0


def _reference(dt, xi, Bm, Cm, A, h0):
    """Plain per-token recurrence (numpy oracle)."""
    B, S, di = dt.shape
    h = np.asarray(h0, np.float64)
    a_all = np.exp(np.asarray(dt)[..., None] * np.asarray(A))
    b_all = (np.asarray(dt) * np.asarray(xi))[..., None] \
        * np.asarray(Bm)[:, :, None, :]
    ys = []
    for t in range(S):
        h = a_all[:, t] * h + b_all[:, t]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(Cm)[:, t]))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("S,w", [(7, 4), (16, 16), (33, 16), (64, 8)])
def test_fused_matches_reference(S, w):
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(0), 2, S, 6, 4)
    y, h = _ssm_scan_fused(dt, dt * xi, Bm, Cm, A, h0, w)
    y_ref, h_ref = _reference(dt, xi, Bm, Cm, A, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 16])
def test_fused_matches_assoc(chunk):
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(1), 2, 24, 8, 4)
    a = jnp.exp(dt[..., None] * A)
    b = (dt * xi)[..., None] * Bm[:, :, None, :]
    y_a, h_a = _ssm_scan(a, b, Cm, h0, chunk)
    y_f, h_f = _ssm_scan_fused(dt, dt * xi, Bm, Cm, A, h0, 8)
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_a),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_a),
                               rtol=2e-5, atol=2e-5)


def test_decode_fast_path_matches_prefill_tail():
    """Running S=1 decode from the S-1 prefill state == full-S scan."""
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(2), 1, 9, 4, 3)
    y_full, h_full = _ssm_scan_fused(dt, dt * xi, Bm, Cm, A, h0, 4)
    y_pre, h_pre = _ssm_scan_fused(
        dt[:, :8], (dt * xi)[:, :8], Bm[:, :8], Cm[:, :8], A, h0, 4)
    y_dec, h_dec = _ssm_scan_fused(
        dt[:, 8:], (dt * xi)[:, 8:], Bm[:, 8:], Cm[:, 8:], A, h_pre, 4)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 8]), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_full),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(S=st.integers(1, 40), w=st.sampled_from([2, 4, 8, 16]),
       seed=st.integers(0, 2**30))
def test_fused_scan_property(S, w, seed):
    """Property: any (S, w) agrees with the numpy recurrence."""
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(seed), 1, S, 4, 2)
    y, h = _ssm_scan_fused(dt, dt * xi, Bm, Cm, A, h0, w)
    y_ref, h_ref = _reference(dt, xi, Bm, Cm, A, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=5e-5, atol=5e-5)


def test_gradients_flow_through_fused_scan():
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(3), 1, 12, 4, 3)

    def loss(dtx):
        y, _ = _ssm_scan_fused(dt, dtx, Bm, Cm, A, h0, 4)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(dt * xi)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0
