"""Decode throughput before/after the frozen-adapter serving cache.

Measures the decode loop (the only part the cache touches per token) in
three configurations on a CPU-runnable smoke config:

  - ``uncached``   — the pre-tentpole path: the factored norm of every
    adapted layer recomputed on EVERY decode token;
  - ``cached``     — g precomputed once by ``precompute_adapter_state``,
    decode does zero norm work per token (bitwise-identical logits);
  - ``cached+gsB`` — g·s additionally folded into B (broadcast-free
    compose; allclose, not bitwise).

Absolute tok/s on this CPU is meaningless for TPU; the *ratio* isolates
exactly the per-token norm work the cache removes, and is recorded in the
committed ``BENCH_serve.json`` to seed the perf trajectory.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--artifact BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core import DoRAConfig
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_precompute_step, make_prefill_step)
from repro.launch.train import build_state


def bench_decode(mcfg, scfg, params, adapters, *, batch, prompt_len,
                 max_len, gen_len, warmup=2):
    """Time ``gen_len`` decode steps against a prefilled cache; returns
    (tok_s, ms_per_token)."""
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, mcfg.vocab_size,
                                    (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=batch,
                                        seq=max_len))
    decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=batch))
    logits, cache = jax.block_until_ready(
        prefill(params, adapters, {"tokens": toks}))
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(warmup):
        logits, _ = decode(params, adapters, cache, {"tokens": nxt})
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    c = cache
    for _ in range(gen_len):
        logits, c = decode(params, adapters, c, {"tokens": nxt})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return batch * gen_len / dt, 1e3 * dt / gen_len


def run(arch="qwen2-7b", *, smoke=True, rank=64, batch=4, prompt_len=16,
        gen_len=32, verbose=True) -> list[dict]:
    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    max_len = prompt_len + gen_len + 4

    t0 = time.perf_counter()
    cached = jax.block_until_ready(jax.jit(
        make_precompute_step(mcfg, scfg))(params, adapters))
    t_pre = time.perf_counter() - t0
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))

    cases = [("uncached", adapters), ("cached", cached),
             ("cached+gsB", folded)]
    rows = []
    base_tok_s = None
    for name, tree in cases:
        tok_s, ms = bench_decode(mcfg, scfg, params, tree, batch=batch,
                                 prompt_len=prompt_len, max_len=max_len,
                                 gen_len=gen_len)
        base_tok_s = base_tok_s or tok_s
        row = {"mode": name, "arch": mcfg.name, "rank": rank,
               "batch": batch, "gen_len": gen_len,
               "tok_s": tok_s, "ms_per_token": ms,
               "speedup_vs_uncached": tok_s / base_tok_s}
        rows.append(row)
        if verbose:
            print(f"  {name:>12}: {tok_s:8.1f} tok/s  ({ms:6.2f} ms/tok, "
                  f"{row['speedup_vs_uncached']:.2f}x)")
    if verbose:
        print(f"  precompute (one-off, amortized over the adapter set): "
              f"{1e3 * t_pre:.1f} ms")
    for r in rows:
        r["precompute_ms"] = 1e3 * t_pre
    save("serve_bench", rows)
    return rows


def write_artifact(rows, path="BENCH_serve.json") -> str:
    payload = {"bench": "serve_decode",
               "rows": rows,
               "notes": "smoke-config CPU decode; the cached/uncached "
                        "ratio isolates the per-token factored-norm work "
                        "removed by precompute_adapter_state."}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short decode, small batch (the MODEL "
                         "is always the smoke config on this CPU "
                         "container; rows record the actual arch name)")
    ap.add_argument("--artifact", default="",
                    help="also write the committed BENCH_serve.json")
    args, _ = ap.parse_known_args()
    gen = 8 if args.smoke else args.gen_len
    batch = 2 if args.smoke else args.batch
    print("# Decode tok/s before/after the frozen-adapter cache")
    rows = run(args.arch, smoke=True, rank=args.rank, batch=batch,
               gen_len=gen)
    if args.artifact:
        print(f"wrote {os.path.abspath(write_artifact(rows, args.artifact))}")


if __name__ == "__main__":
    main()
