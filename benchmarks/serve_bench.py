"""Decode throughput before/after the frozen-adapter serving cache.

Measures the decode loop (the only part the cache touches per token) in
three configurations on a CPU-runnable smoke config:

  - ``uncached``   — the pre-tentpole path: the factored norm of every
    adapted layer recomputed on EVERY decode token;
  - ``cached``     — g precomputed once by ``precompute_adapter_state``,
    decode does zero norm work per token (bitwise-identical logits);
  - ``cached+gsB`` — g·s additionally folded into B (broadcast-free
    compose; allclose, not bitwise).

The multi-tenant section prices the request-routed server: ``mt-warm``
(every adapter state an LRU hit) and ``mt-cold`` (empty cache: the first
batch pays one precompute per tenant) against the single-tenant
``cached+gsB`` decode, plus the ANALYTIC per-token adapter-path bytes
model (``adapter_decode_bytes_model``) — where the cache-hit grouped path
prices IDENTICALLY to single-tenant cached decode by construction (each
row reads its own A/gsB/g once, no norm reads); the equality is gated in
``scripts/check_bench_drift.py``.

The continuous section prices the slot-scheduled engine
(``repro.launch.engine``) against static batches under one Poisson-ish
arrival trace: the DETERMINISTIC schedule model (decode steps and mean
slot occupancy from ``simulate_continuous``/``simulate_static`` — pure
host arithmetic mirroring the engine's admission/retirement policy,
asserted against the real engine's counters) is committed and gated in
``scripts/check_bench_drift.py`` (the engine must beat the static
baseline, which pays idle-row decode); measured tok/s stays
informational.

The paged section prices the BLOCK-PAGED engine (``paged=True``:
pooled K/V blocks + chunked prefill) on a long-context variant of the
same trace (one 48-token prompt among the 8-token neighbours): the
schedule AND block-occupancy model (``simulate_paged`` — the same
pure-host mirror, extended with the engine's block reserve/grow/free
accounting) is asserted against the real paged engine's counters and
``pool_stats()``, and the MEMORY model (``paged_cache_bytes_model`` —
peak resident block bytes vs the rectangular ``slots * max_len``
reservation, pure shape arithmetic) is committed and gated in
``scripts/check_bench_drift.py`` (paged must stay strictly under the
rectangular reservation for this trace).

The fleet section prices THOUSAND-ADAPTER serving (``dynamic_grouping``)
on a churny multi-tenant trace (N tenants ≫ slots): the SIGNATURE model
(``simulate_fleet`` — the static engine compiles one decode executable
per distinct slot layout the trace visits, the dynamic engine exactly
ONE) is asserted against both real engines along with the bitwise
dynamic-vs-static stream oracle, and the ADMISSION model
(``fleet_admission_bytes_model`` — a host-spilled tenant re-admits for
one state copy, a cold tenant pays the full W-reading precompute) is
committed and gated in ``scripts/check_bench_drift.py``
(``check_fleet``: spilled must stay strictly cheaper than cold).

Absolute tok/s on this CPU is meaningless for TPU; the *ratio* isolates
exactly the per-token norm work the cache removes, and is recorded in the
committed ``BENCH_serve.json`` to seed the perf trajectory.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--artifact BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_precompute_step, make_prefill_step)
from repro.launch.train import build_state


def bench_decode(mcfg, scfg, params, adapters, *, batch, prompt_len,
                 max_len, gen_len, warmup=2, tenant_groups=None):
    """Time ``gen_len`` decode steps against a prefilled cache; returns
    (tok_s, ms_per_token). ``tenant_groups``: time the GROUPED multi-
    tenant decode step instead (same loop, adapter routing inside)."""
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, mcfg.vocab_size,
                                    (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=batch,
                                        seq=max_len,
                                        tenant_groups=tenant_groups))
    decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=batch,
                                      tenant_groups=tenant_groups))
    logits, cache = jax.block_until_ready(
        prefill(params, adapters, {"tokens": toks}))
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(warmup):
        logits, _ = decode(params, adapters, cache, {"tokens": nxt})
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    c = cache
    for _ in range(gen_len):
        logits, c = decode(params, adapters, c, {"tokens": nxt})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return batch * gen_len / dt, 1e3 * dt / gen_len


def run(arch="qwen2-7b", *, smoke=True, rank=64, batch=4, prompt_len=16,
        gen_len=32, verbose=True) -> list[dict]:
    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    max_len = prompt_len + gen_len + 4

    t0 = time.perf_counter()
    cached = jax.block_until_ready(jax.jit(
        make_precompute_step(mcfg, scfg))(params, adapters))
    t_pre = time.perf_counter() - t0
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))

    cases = [("uncached", adapters), ("cached", cached),
             ("cached+gsB", folded)]
    rows = []
    base_tok_s = None
    for name, tree in cases:
        tok_s, ms = bench_decode(mcfg, scfg, params, tree, batch=batch,
                                 prompt_len=prompt_len, max_len=max_len,
                                 gen_len=gen_len)
        base_tok_s = base_tok_s or tok_s
        row = {"mode": name, "arch": mcfg.name, "rank": rank,
               "batch": batch, "gen_len": gen_len,
               "tok_s": tok_s, "ms_per_token": ms,
               "speedup_vs_uncached": tok_s / base_tok_s}
        rows.append(row)
        if verbose:
            print(f"  {name:>12}: {tok_s:8.1f} tok/s  ({ms:6.2f} ms/tok, "
                  f"{row['speedup_vs_uncached']:.2f}x)")
    if verbose:
        print(f"  precompute (one-off, amortized over the adapter set): "
              f"{1e3 * t_pre:.1f} ms")
    for r in rows:
        r["precompute_ms"] = 1e3 * t_pre
    save("serve_bench", rows)
    return rows


# ---------------------------------------------------------------------------
# Multi-tenant serving (LRU adapter-state cache + grouped decode).
# ---------------------------------------------------------------------------

def adapter_decode_bytes_model(d_out: int, d_in: int, rank: int,
                               dtype_size: int = 4) -> dict:
    """ANALYTIC per-token, per-row, per-adapted-layer HBM reads of the
    ADAPTER path (the base y = x@Wᵀ is mode-independent and excluded):

      - ``uncached``: the factored norm re-reads W [d_out, d_in] (the
        base-squared term) + A + B + m every token, then the compose
        reads A + B + g again — the W read dominates;
      - ``cached``: A + B + the cached g (no W, no norm);
      - ``cached_gsb``: A + the folded gsB (same size as B) + g;
      - ``mt_hit``: the multi-tenant grouped path on a cache HIT — each
        row reads ITS OWN A[k]/gsB[k]/g[k] exactly once, so it prices
        IDENTICALLY to ``cached_gsb`` (gated: a multi-tenant design that
        priced worse than single-tenant cached decode would be a
        regression, not a feature).

    Pure integer arithmetic — machine-independent, transfers to TPU, and
    is the committed "model" section of BENCH_serve.json that
    ``scripts/check_bench_drift.py`` re-prices.
    """
    a = rank * d_in * dtype_size
    b = d_out * rank * dtype_size
    vec = d_out * dtype_size          # m / g / w_norm row vectors (fp32)
    w = d_out * d_in * dtype_size
    # uncached = the norm pass (W, A, B, m) PLUS the compose pass
    # (A, B, g) — A/B are read twice per token; the W read dominates.
    uncached = (w + a + b + vec) + (a + b + vec)
    cached = a + b + vec              # compose reads A, B + cached g
    cached_gsb = a + b + vec          # A + gsB (|gsB| == |B|) + g
    return {
        "d_out": d_out, "d_in": d_in, "rank": rank,
        "dtype_size": dtype_size,
        "uncached_bytes": uncached,
        "cached_bytes": cached,
        "cached_gsb_bytes": cached_gsb,
        "mt_hit_bytes": cached_gsb,   # identical pricing BY CONSTRUCTION
        "model_ratio_uncached_over_cached": uncached / cached,
    }


def run_multitenant(arch="qwen2-7b", *, smoke=True, rank=64, tenants=3,
                    rows_per=2, prompt_len=16, gen_len=32,
                    verbose=True) -> dict:
    """Cold-miss vs warm-hit multi-tenant serving vs single-tenant cached
    decode; returns {"rows": [...], "model": {...}, "cache": stats}.

    All three rows time the SAME decode loop (``bench_decode``), so the
    ratio isolates exactly the grouped adapter routing: warm-hit pays the
    per-row gsB gather, cold-miss additionally amortizes one LRU
    precompute per tenant over the batch's tokens."""
    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    max_len = prompt_len + gen_len + 4
    B = tenants * rows_per
    rng = np.random.default_rng(0)

    cache = AdapterStateCache.for_serving(mcfg, scfg)
    handles = []
    for t in range(tenants):
        _, ad_t, _ = build_state(mcfg, dcfg, 10 + t)
        handles.append(cache.register(f"tenant-{t}", ad_t))

    # Single-tenant baseline: the SAME batch size, one adapter, folded
    # state — the tok/s the grouped cache-hit path must not fall behind.
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    st_tok_s, st_ms = bench_decode(mcfg, scfg, params, folded, batch=B,
                                   prompt_len=prompt_len, max_len=max_len,
                                   gen_len=gen_len)

    # Warm-hit: every state an LRU hit; time the grouped decode loop.
    from repro.core import stack_adapter_states
    groups = tuple((t * rows_per, rows_per) for t in range(tenants))
    states = [cache.get_state(params, h) for h in handles]   # cold misses
    stacked = stack_adapter_states(states, axis=1)
    warm_tok_s, warm_ms = bench_decode(mcfg, scfg, params, stacked,
                                       batch=B, prompt_len=prompt_len,
                                       max_len=max_len, gen_len=gen_len,
                                       tenant_groups=groups)

    # Cold-miss: drop the cached states (registry intact) and re-derive
    # them through the LRU — the recompute cost amortized over this
    # batch's tokens is the miss penalty.
    cache.invalidate()
    t0 = time.perf_counter()
    states = [cache.get_state(params, h) for h in handles]
    stacked = jax.block_until_ready(
        stack_adapter_states(states, axis=1))
    t_miss = time.perf_counter() - t0
    dt_decode = B * gen_len / warm_tok_s
    cold_tok_s = B * gen_len / (dt_decode + t_miss)
    cold_ms = 1e3 * (dt_decode + t_miss) / gen_len

    rows = [
        {"mode": "single-tenant cached+gsB", "tok_s": st_tok_s,
         "ms_per_token": st_ms},
        {"mode": "mt-warm", "tok_s": warm_tok_s, "ms_per_token": warm_ms,
         "vs_single_tenant": warm_tok_s / st_tok_s},
        {"mode": "mt-cold", "tok_s": cold_tok_s, "ms_per_token": cold_ms,
         "vs_single_tenant": cold_tok_s / st_tok_s,
         "miss_precompute_ms": 1e3 * t_miss},
    ]
    for r in rows:
        r.update(arch=mcfg.name, rank=rank, tenants=tenants,
                 batch=B, gen_len=gen_len)
    model = adapter_decode_bytes_model(mcfg.d_model, mcfg.d_model, rank)
    stats = cache.stats().as_dict()
    if verbose:
        for r in rows:
            extra = (f" ({r['vs_single_tenant']:.2f}x vs single-tenant)"
                     if "vs_single_tenant" in r else "")
            print(f"  {r['mode']:>26}: {r['tok_s']:8.1f} tok/s "
                  f"({r['ms_per_token']:6.2f} ms/tok){extra}")
        print(f"  cache: {stats['hits']} hits / {stats['misses']} misses "
              f"/ {stats['current_bytes']} state bytes; analytic "
              f"mt_hit == cached_gsb: "
              f"{model['mt_hit_bytes'] == model['cached_gsb_bytes']}")
    save("serve_bench_multitenant", rows)
    return {"rows": rows, "model": model, "cache": stats}


# ---------------------------------------------------------------------------
# Continuous batching (slot-scheduled engine vs static batches).
# ---------------------------------------------------------------------------

def make_arrival_trace(*, n_requests=12, mean_interarrival=2.0,
                       prompt_len=8, gen_lens=(4, 6, 8, 10), seed=0):
    """Poisson-ish arrival trace: exponential inter-arrival times in
    decode-step units, per-request token budgets drawn from ``gen_lens``.
    Deterministic given the parameters — the committed
    ``BENCH_serve.json`` records them and ``scripts/check_bench_drift.py``
    re-simulates the schedule from them (no model math involved)."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(mean_interarrival)
        reqs.append({"arrival_step": int(t),
                     "prompt_len": int(prompt_len),
                     "gen_len": int(rng.choice(gen_lens))})
    return reqs


def simulate_continuous(trace, *, slots: int) -> dict:
    """Pure-host mirror of the engine's scheduling (admission is FIFO
    into free slots, one token per active slot per decode step, rows
    retire at their budget) driven by the same arrival loop
    ``run_continuous`` drives the real engine with. Scheduling is
    model-independent when no EOS is set, so these counters are exactly
    the real engine's — ``run_continuous`` asserts that."""
    from collections import deque
    queue: deque = deque()
    table = [None] * slots      # remaining tokens per busy slot
    i, step = 0, 0
    decode_steps = prefills = generated = slot_steps = 0
    n = len(trace)

    def has_work():
        return bool(queue) or any(v is not None for v in table)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            queue.append(trace[i])
            i += 1
        for j in range(slots):
            while table[j] is None and queue:
                r = queue.popleft()
                prefills += 1
                generated += 1                  # first token from prefill
                if r["gen_len"] - 1 > 0:
                    table[j] = r["gen_len"] - 1
        active = [j for j in range(slots) if table[j] is not None]
        if active:
            decode_steps += 1
            slot_steps += len(active)
            for j in active:
                generated += 1
                table[j] -= 1
                if table[j] == 0:
                    table[j] = None
        step += 1
    occ = slot_steps / (decode_steps * slots) if decode_steps else 0.0
    return {"steps": step, "decode_steps": decode_steps,
            "prefills": prefills, "generated_tokens": generated,
            "slot_steps": slot_steps, "mean_occupancy": occ}


def simulate_degraded(trace, *, slots: int, preempt_step: int,
                      quarantine_step: int) -> dict:
    """:func:`simulate_continuous` under one preemption + one quarantine
    — the deterministic mirror of the engine's fault containment that
    ``scripts/check_bench_drift.py`` gates (``check_degraded``).

    At the first tick ``>= preempt_step`` with active rows, the
    lowest-index active row (remaining budget ``b``) is displaced and
    re-queued as a continuation of ``gen_len=b`` (the engine re-prefills
    prompt+generated into a free row: its admission emits one token and
    ``b - 1`` decode steps finish it, so the preempted request still
    produces every token — the preempting high-priority request itself is
    abstracted away, since it would have been served either way and nets
    out of the clean-vs-degraded comparison). At the first tick
    ``>= quarantine_step`` with active rows, the lowest-index active row
    does its decode row-work (``slot_steps`` counts it — the poisoned
    logits are only detected AFTER the batched forward) but emits
    nothing and retires; its remaining budget is lost.

    Returns the :func:`simulate_continuous` dict plus ``lost_tokens``
    (the quarantined row's undelivered budget), ``displaced_steps`` (the
    preempted row's remaining budget — the ceiling on extra decode
    steps) and ``extra_prefills`` (the one continuation re-prefill).
    The containment contract, gated against the clean schedule:
    tokens lost == lost_tokens exactly, prefills grow by exactly
    extra_prefills, decode steps grow by at most displaced_steps."""
    from collections import deque
    queue: deque = deque()
    table = [None] * slots
    i, step = 0, 0
    decode_steps = prefills = generated = slot_steps = 0
    lost_tokens = displaced_steps = extra_prefills = 0
    preempt_done = quarantine_done = False
    n = len(trace)

    def has_work():
        return bool(queue) or any(v is not None for v in table)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            queue.append(trace[i])
            i += 1
        for j in range(slots):
            while table[j] is None and queue:
                r = queue.popleft()
                prefills += 1
                generated += 1                  # first token from prefill
                if r["gen_len"] - 1 > 0:
                    table[j] = r["gen_len"] - 1
        if not preempt_done and step >= preempt_step:
            victims = [j for j in range(slots) if table[j] is not None]
            if victims:
                v = victims[0]
                displaced_steps = table[v]
                extra_prefills = 1
                queue.append({"arrival_step": step,
                              "prompt_len": trace[0]["prompt_len"],
                              "gen_len": table[v]})
                table[v] = None
                preempt_done = True
        active = [j for j in range(slots) if table[j] is not None]
        if active:
            decode_steps += 1
            slot_steps += len(active)
            doomed = None
            if not quarantine_done and step >= quarantine_step:
                doomed = active[0]
                quarantine_done = True
            for j in active:
                if j == doomed:
                    # row-work spent, no token delivered: the remaining
                    # budget (this tick's token included) is lost.
                    lost_tokens = table[j]
                    table[j] = None
                    continue
                generated += 1
                table[j] -= 1
                if table[j] == 0:
                    table[j] = None
        step += 1
    occ = slot_steps / (decode_steps * slots) if decode_steps else 0.0
    return {"steps": step, "decode_steps": decode_steps,
            "prefills": prefills, "generated_tokens": generated,
            "slot_steps": slot_steps, "mean_occupancy": occ,
            "lost_tokens": lost_tokens,
            "displaced_steps": displaced_steps,
            "extra_prefills": extra_prefills}


def simulate_static(trace, *, slots: int) -> dict:
    """The static-batch baseline on the SAME trace: an idle server takes
    up to ``slots`` arrived requests FCFS and decodes the whole batch for
    ``max(gen_len)`` steps (the legacy retirement unit is the batch — a
    short request burns its row until the longest one drains, and a
    partial batch burns its empty rows too). Useful decode tokens per
    row are ``gen_len - 1`` (first token comes from prefill), so
    occupancy = useful / (slots * decode_steps)."""
    i, t = 0, 0
    queue: list = []
    decode_steps = useful = 0
    batches = []
    n = len(trace)
    while i < n or queue:
        while i < n and trace[i]["arrival_step"] <= t:
            queue.append(trace[i])
            i += 1
        if not queue:
            t += 1
            continue
        batch, queue = queue[:slots], queue[slots:]
        steps_b = max(r["gen_len"] for r in batch)
        decode_steps += steps_b
        useful += sum(r["gen_len"] - 1 for r in batch)
        batches.append([r["gen_len"] for r in batch])
        t += steps_b
    occ = useful / (decode_steps * slots) if decode_steps else 0.0
    return {"decode_steps": decode_steps, "useful_decode_tokens": useful,
            "batches": batches,
            "mean_occupancy": occ}


def simulate_speculative(trace, *, slots: int, max_len: int, k: int,
                         accept_rate: float = 1.0) -> dict:
    """Pure-host mirror of the SPECULATIVE engine's scheduling: same FIFO
    admission/retirement as :func:`simulate_continuous`, but a tick where
    every active row's k+1 window fits under ``max_len`` runs k draft
    forwards + ONE verify, each row emitting ``min(a + 1, budget)``
    tokens (``a`` accepted drafts plus the verify's own token); ticks
    with a row at its max_len cap fall back to a plain decode step — the
    engine's exact policy.

    ``accept_rate`` sets the deterministic per-row accepted-draft count
    ``a = round(accept_rate * k)``. At 1.0 this mirrors the benchmark
    engine EXACTLY: the bench adapters are B=0 identity, so the base-only
    draft is bitwise the full path and every draft is accepted —
    ``run_speculative`` asserts all seven counters against the real
    engine. Lower rates model a tenant whose adapter diverges from the
    base (fewer tokens per verify, more verify steps)."""
    a_const = int(round(accept_rate * k))
    if not 0 <= a_const <= k:
        raise ValueError(f"accept_rate={accept_rate} with k={k}")
    from collections import deque
    queue: deque = deque()
    table = [None] * slots      # [remaining budget, next write pos]
    i, step = 0, 0
    decode_steps = prefills = generated = slot_steps = 0
    draft_steps = verify_steps = accepted = 0
    n = len(trace)

    def has_work():
        return bool(queue) or any(v is not None for v in table)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            queue.append(trace[i])
            i += 1
        for j in range(slots):
            while table[j] is None and queue:
                r = queue.popleft()
                prefills += 1
                generated += 1                  # first token from prefill
                if r["gen_len"] - 1 > 0:
                    table[j] = [r["gen_len"] - 1, r["prompt_len"]]
        active = [j for j in range(slots) if table[j] is not None]
        if active:
            if all(table[j][1] + k + 1 <= max_len for j in active):
                draft_steps += k
                verify_steps += 1
                for j in active:
                    accepted += a_const
                    emit = min(a_const + 1, table[j][0])
                    generated += emit
                    table[j][0] -= emit
                    table[j][1] += emit
                    if table[j][0] == 0:
                        table[j] = None
            else:
                decode_steps += 1
                slot_steps += len(active)
                for j in active:
                    generated += 1
                    table[j][0] -= 1
                    table[j][1] += 1
                    if table[j][0] == 0:
                        table[j] = None
        step += 1
    return {"steps": step, "decode_steps": decode_steps,
            "prefills": prefills, "generated_tokens": generated,
            "slot_steps": slot_steps, "draft_steps": draft_steps,
            "verify_steps": verify_steps, "accepted_drafts": accepted}


def _drive_engine(engine, trace, prompts, gen_lens):
    """The arrival loop ``simulate_continuous`` mirrors: submit requests
    as their arrival step comes due, tick the engine once per step."""
    i, step = 0, 0
    while i < len(trace) or engine.has_work():
        while i < len(trace) and trace[i]["arrival_step"] <= step:
            engine.submit(prompts[i], max_new_tokens=gen_lens[i])
            i += 1
        engine.step()
        step += 1


def run_continuous(arch="qwen2-7b", *, smoke=True, rank=64, slots=4,
                   verbose=True) -> dict:
    """Continuous-batching engine vs static batches under one arrival
    trace. The SCHEDULE model (decode steps, occupancy) is deterministic
    and machine-independent — committed and gated; wall-clock tok/s is
    informational. Also asserts the pure-host simulation reproduces the
    real engine's counters exactly (scheduling is model-independent)."""
    from repro.launch.engine import DecodeEngine

    trace_params = {"n_requests": 12, "mean_interarrival": 2.0,
                    "prompt_len": 8, "gen_lens": (4, 6, 8, 10), "seed": 0}
    trace = make_arrival_trace(**trace_params)
    max_len = trace_params["prompt_len"] + max(trace_params["gen_lens"])
    sim_e = simulate_continuous(trace, slots=slots)
    sim_s = simulate_static(trace, slots=slots)

    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, r["prompt_len"],
                            dtype=np.int32) for r in trace]
    gen_lens = [r["gen_len"] for r in trace]

    # Real engine over the trace: first pass compiles, second is timed.
    engine = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                          adapters=folded)
    _drive_engine(engine, trace, prompts, gen_lens)
    st1 = engine.stats()
    for field in ("decode_steps", "prefills", "generated_tokens",
                  "slot_steps"):
        got = getattr(st1, field)
        want = sim_e[field]
        assert got == want, (
            f"engine {field}={got} but the committed scheduling model "
            f"says {want} — simulate_continuous no longer mirrors the "
            f"engine; fix one of them before regenerating the artifact")
    t0 = time.perf_counter()
    _drive_engine(engine, trace, prompts, gen_lens)
    dt_e = time.perf_counter() - t0
    eng_tok_s = sim_e["generated_tokens"] / dt_e

    # Static baseline: the simulated FCFS batches through the legacy
    # static loop (same prompt-length bucket by construction), each batch
    # decoding to its longest request. Steps are jitted ONCE per batch
    # size (like MultiTenantServer's step cache) so the timed second pass
    # measures the loop, not compiles.
    from repro.launch.serve import _decode_loop
    P = trace_params["prompt_len"]
    static_steps: dict = {}

    def _static_steps(b):
        if b not in static_steps:
            static_steps[b] = (
                jax.jit(make_prefill_step(mcfg, scfg, None, batch=b,
                                          seq=max_len, padded=True)),
                jax.jit(make_decode_step(mcfg, scfg, None, batch=b)))
        return static_steps[b]

    def _serve_static():
        k = 0
        for batch in sim_s["batches"]:
            b = len(batch)
            toks = jnp.asarray(np.stack(prompts[k:k + b]))
            prefill, decode = _static_steps(b)
            _decode_loop(prefill, decode, params, folded, toks,
                         prompt_len=P, gen_len=max(batch),
                         pad=max_len - P, temperature=0.0, seed=0)
            k += b

    _serve_static()
    t0 = time.perf_counter()
    _serve_static()
    dt_s = time.perf_counter() - t0
    # useful-token throughput: the static loop also generated the
    # over-length padding tokens, but only sum(gen_len) were asked for.
    static_tok_s = sim_e["generated_tokens"] / dt_s

    out = {"trace": dict(trace_params, slots=slots, max_len=max_len,
                         gen_lens=list(trace_params["gen_lens"])),
           "engine_model": sim_e,
           "static_model": sim_s,
           "model_step_ratio_static_over_engine":
               sim_s["decode_steps"] / sim_e["decode_steps"],
           "measured": {"engine_tok_s": eng_tok_s,
                        "static_tok_s": static_tok_s,
                        "engine_vs_static": eng_tok_s / static_tok_s}}
    if verbose:
        print(f"  engine: {sim_e['decode_steps']} decode steps, occupancy "
              f"{sim_e['mean_occupancy']:.2f}, {eng_tok_s:.1f} tok/s "
              f"(measured)")
        print(f"  static: {sim_s['decode_steps']} decode steps, occupancy "
              f"{sim_s['mean_occupancy']:.2f}, {static_tok_s:.1f} tok/s "
              f"(measured, useful tokens)")
        print(f"  model ratio static/engine decode steps: "
              f"{out['model_step_ratio_static_over_engine']:.2f}x; "
              f"measured engine/static tok/s: "
              f"{out['measured']['engine_vs_static']:.2f}x")
    save("serve_bench_continuous", [out])
    return out


def _tick_pcts(xs) -> dict:
    """p50/p90/max summary of a tick-valued sample, via the SAME
    nearest-rank percentile the obs layer exports (repro.obs.percentile)
    so the committed numbers and the trace-derived ones share one
    definition."""
    from repro.obs import percentile
    return {"p50": percentile(xs, 50), "p90": percentile(xs, 90),
            "max": float(max(xs)) if xs else 0.0}


def simulate_obs(trace, *, slots: int) -> dict:
    """Pure-host per-request LIFECYCLE model over the arrival trace: the
    same admission loop as :func:`simulate_continuous`, but recording the
    ticks a ``repro.obs.TraceRecorder`` would stamp on each request's
    ``submitted`` / ``admitted`` / ``first_token`` / ``terminal`` events
    (submission lands at the arrival step; admission == prefill emits the
    first token; the terminal rides the last token's tick). From those,
    the tick-domain latency percentiles the obs section commits:
    queue wait (submit -> admit), TTFT (submit -> first token),
    admit-to-retire, and per-decode-tick slot occupancy.

    ``run_obs`` asserts a traced REAL engine derives identical numbers
    via ``repro.obs.lifecycle_latencies``, and ``check_obs`` in
    ``scripts/check_bench_drift.py`` re-simulates this model from the
    committed trace parameters and hard-fails when queue-wait p50
    regresses."""
    from collections import deque
    queue: deque = deque()
    table = [None] * slots          # (request index, remaining) per slot
    i, step = 0, 0
    n = len(trace)
    sub = [None] * n
    adm = [None] * n
    term = [None] * n
    occ_per_tick: list = []

    def has_work():
        return bool(queue) or any(v is not None for v in table)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            sub[i] = step
            queue.append((i, trace[i]["gen_len"]))
            i += 1
        for j in range(slots):
            while table[j] is None and queue:
                ridx, g = queue.popleft()
                adm[ridx] = step        # prefill: first token at this tick
                if g - 1 > 0:
                    table[j] = (ridx, g - 1)
                else:
                    term[ridx] = step   # one-token request retires in prefill
        active = [j for j in range(slots) if table[j] is not None]
        if active:
            occ_per_tick.append(len(active))
            for j in active:
                ridx, rem = table[j]
                rem -= 1
                if rem == 0:
                    term[ridx] = step
                    table[j] = None
                else:
                    table[j] = (ridx, rem)
        step += 1

    queue_wait = [a - s for s, a in zip(sub, adm)]
    admit_to_retire = [t - a for a, t in zip(adm, term)]
    return {"n_requests": n,
            "queue_wait_ticks": _tick_pcts(queue_wait),
            # first token comes FROM the admission prefill, so TTFT and
            # queue wait coincide tick-for-tick in the rectangular
            # engine; committing both makes the equality an asserted
            # structural fact, not an accident.
            "ttft_ticks": _tick_pcts(queue_wait),
            "admit_to_retire_ticks": _tick_pcts(admit_to_retire),
            "occupancy": {"p50": _tick_pcts(occ_per_tick)["p50"],
                          "mean": (sum(occ_per_tick) / (len(occ_per_tick)
                                   * slots) if occ_per_tick else 0.0)}}


def run_obs(arch="qwen2-7b", *, smoke=True, rank=64, slots=4,
            verbose=True) -> dict:
    """Observability section: drive a TRACED engine over the SAME
    committed arrival trace as ``run_continuous``, derive the tick-domain
    latency percentiles from the trace (``repro.obs
    .lifecycle_latencies``), and assert them EQUAL to the pure-host
    lifecycle model — the trace is a faithful journal of the
    host-mirror schedule, not a sampled approximation. Wall-clock (s)
    percentiles ride along informationally; they are machine-dependent
    and never gated.

    The trace is the continuous section's generator at a 4x tighter
    inter-arrival (0.5 vs 2.0) — at 2.0 the 4-slot engine admits every
    request instantly and queue wait is identically zero, which would
    make the queue-wait gate vacuous; at 0.5 the queue actually forms
    (p50 = 2 ticks, slots saturate) so the gated percentiles measure
    real scheduler behaviour."""
    from collections import Counter

    from repro.launch.engine import DecodeEngine
    from repro.obs import TraceRecorder, lifecycle_latencies, percentile

    trace_params = {"n_requests": 12, "mean_interarrival": 0.5,
                    "prompt_len": 8, "gen_lens": (4, 6, 8, 10), "seed": 0}
    trace = make_arrival_trace(**trace_params)
    max_len = trace_params["prompt_len"] + max(trace_params["gen_lens"])
    model = simulate_obs(trace, slots=slots)

    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, r["prompt_len"],
                            dtype=np.int32) for r in trace]
    gen_lens = [r["gen_len"] for r in trace]

    rec = TraceRecorder()
    engine = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                          adapters=folded, trace=rec)
    _drive_engine(engine, trace, prompts, gen_lens)
    assert rec.dropped == 0, "default ring must hold the smoke trace"
    lat = lifecycle_latencies(rec)
    assert len(lat) == len(trace), (
        f"trace covers {len(lat)} requests, submitted {len(trace)}")

    qw = [r["queue_wait_ticks"] for r in lat.values()]
    tt = [r["ttft_ticks"] for r in lat.values()]
    a2r = [r["admit_to_retire_ticks"] for r in lat.values()]
    # Decode-tick occupancy straight off the event stream: one "token"
    # event per active row per decode tick ("first_token" is prefill's).
    per_tick = Counter(e.tick for e in rec if e.name == "token")
    occ = [per_tick[t] for t in sorted(per_tick)]
    traced = {"queue_wait_ticks": _tick_pcts(qw),
              "ttft_ticks": _tick_pcts(tt),
              "admit_to_retire_ticks": _tick_pcts(a2r),
              "occupancy": {"p50": _tick_pcts(occ)["p50"],
                            "mean": (sum(occ) / (len(occ) * slots)
                                     if occ else 0.0)}}
    for key in ("queue_wait_ticks", "ttft_ticks", "admit_to_retire_ticks",
                "occupancy"):
        assert traced[key] == model[key], (
            f"trace-derived {key}={traced[key]} but the lifecycle model "
            f"says {model[key]} — the TraceRecorder no longer journals "
            f"the host-mirror schedule faithfully (or simulate_obs "
            f"drifted); fix one of them before regenerating the artifact")

    wall = {"ttft_s_p50": percentile(
                [r["ttft_s"] for r in lat.values()
                 if r["ttft_s"] is not None], 50),
            "admit_to_retire_s_p50": percentile(
                [r["admit_to_retire_s"] for r in lat.values()
                 if r["admit_to_retire_s"] is not None], 50)}

    out = {"trace": dict(trace_params, slots=slots, max_len=max_len,
                         gen_lens=list(trace_params["gen_lens"])),
           "lifecycle_model": model,
           "traced_engine": traced,     # asserted == lifecycle_model
           "events": {"emitted": rec.emitted, "dropped": rec.dropped},
           "measured_wall_s": wall}     # informational, never gated
    if verbose:
        print(f"  lifecycle over {model['n_requests']} requests: "
              f"queue-wait p50/p90/max "
              f"{model['queue_wait_ticks']['p50']:.0f}/"
              f"{model['queue_wait_ticks']['p90']:.0f}/"
              f"{model['queue_wait_ticks']['max']:.0f} ticks, "
              f"ttft p50 {model['ttft_ticks']['p50']:.0f}, "
              f"occupancy p50 {model['occupancy']['p50']:.0f} slots")
        print(f"  traced engine == model across all percentiles "
              f"({rec.emitted} events, {rec.dropped} dropped); "
              f"wall ttft p50 {wall['ttft_s_p50'] * 1e3:.2f} ms "
              f"(informational)")
    save("serve_bench_obs", [out])
    return out


def run_speculative(arch="qwen2-7b", *, smoke=True, rank=64, slots=4,
                    k=3, verbose=True) -> dict:
    """Speculative vs plain decode under the SAME committed arrival trace
    as ``run_continuous``. Deterministic and gated twice over:

      - the accept-rate schedule model (``simulate_speculative``) at
        accept_rate=1.0 must reproduce the real identity-adapter engine's
        counters EXACTLY (asserted here, like ``simulate_continuous``);
      - the committed model must show speculative needing FEWER full-DoRA
        verify steps than plain decode emits tokens (every plain decode
        step is one full-DoRA forward per token; gated in
        ``scripts/check_bench_drift.py`` — including at the degraded
        accept rate, so the win can't silently hinge on perfect drafts).

    The greedy token streams of the two engines are asserted bitwise
    identical (the tentpole's oracle)."""
    from repro.launch.engine import DecodeEngine

    trace_params = {"n_requests": 12, "mean_interarrival": 2.0,
                    "prompt_len": 8, "gen_lens": (4, 6, 8, 10), "seed": 0}
    degraded_rate = 0.5
    trace = make_arrival_trace(**trace_params)
    max_len = trace_params["prompt_len"] + max(trace_params["gen_lens"])
    sim_spec = simulate_speculative(trace, slots=slots, max_len=max_len,
                                    k=k, accept_rate=1.0)
    sim_degraded = simulate_speculative(trace, slots=slots,
                                        max_len=max_len, k=k,
                                        accept_rate=degraded_rate)
    sim_plain = simulate_continuous(trace, slots=slots)

    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, r["prompt_len"],
                            dtype=np.int32) for r in trace]
    gen_lens = [r["gen_len"] for r in trace]

    spec = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                        adapters=folded, speculative_k=k)
    _drive_engine(spec, trace, prompts, gen_lens)
    st = spec.stats()
    for field in ("decode_steps", "prefills", "generated_tokens",
                  "slot_steps", "draft_steps", "verify_steps",
                  "accepted_drafts"):
        got = getattr(st, field)
        want = sim_spec[field]
        assert got == want, (
            f"speculative engine {field}={got} but the committed schedule "
            f"model says {want} — simulate_speculative no longer mirrors "
            f"the engine (or the B=0 bench adapters stopped drafting "
            f"perfectly); fix before regenerating the artifact")
    spec_tokens = {r.request_id: r.tokens.tolist()
                   for r in spec.pop_results()}

    plain = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                         adapters=folded)
    _drive_engine(plain, trace, prompts, gen_lens)
    plain_tokens = {r.request_id: r.tokens.tolist()
                    for r in plain.pop_results()}
    assert spec_tokens == plain_tokens, (
        "greedy speculative streams diverged from plain decode — the "
        "bitwise oracle is broken", spec_tokens, plain_tokens)

    # timed second pass (compiles are warm)
    t0 = time.perf_counter()
    _drive_engine(spec, trace, prompts, gen_lens)
    dt_spec = time.perf_counter() - t0
    t0 = time.perf_counter()
    _drive_engine(plain, trace, prompts, gen_lens)
    dt_plain = time.perf_counter() - t0

    out = {"trace": dict(trace_params, slots=slots, max_len=max_len, k=k,
                         gen_lens=list(trace_params["gen_lens"]),
                         degraded_accept_rate=degraded_rate),
           "speculative_model": sim_spec,
           "degraded_model": sim_degraded,
           "plain_model": {"decode_steps": sim_plain["decode_steps"],
                           "generated_tokens":
                               sim_plain["generated_tokens"]},
           "model_verify_vs_plain_tokens":
               (sim_spec["verify_steps"] + sim_spec["decode_steps"])
               / sim_plain["generated_tokens"],
           "measured": {"spec_s": dt_spec, "plain_s": dt_plain,
                        "plain_vs_spec": dt_plain / dt_spec}}
    if verbose:
        print(f"  speculative (k={k}): {sim_spec['verify_steps']} verify "
              f"+ {sim_spec['decode_steps']} fallback decode steps for "
              f"{sim_spec['generated_tokens']} tokens "
              f"(plain: {sim_plain['decode_steps']} decode steps); "
              f"degraded accept={degraded_rate}: "
              f"{sim_degraded['verify_steps']} verify + "
              f"{sim_degraded['decode_steps']} decode")
        print(f"  oracle: greedy speculative streams == plain (bitwise); "
              f"measured plain/spec wall: "
              f"{out['measured']['plain_vs_spec']:.2f}x")
    save("serve_bench_speculative", [out])
    return out


# ---------------------------------------------------------------------------
# Paged KV cache + chunked prefill (block pool vs rectangular HBM).
# ---------------------------------------------------------------------------

def make_longcontext_trace(trace_params, *, long_arrival: int,
                           long_prompt_len: int, long_gen_len: int):
    """The committed short-request arrival trace with ONE long prompt
    spliced in at ``long_arrival`` (in arrival order — the engine's FIFO
    queue sees it exactly where a real long-context tenant would land).
    Deterministic given the parameters; ``scripts/check_bench_drift.py``
    rebuilds it from the committed artifact."""
    trace = make_arrival_trace(**trace_params)
    req = {"arrival_step": int(long_arrival),
           "prompt_len": int(long_prompt_len),
           "gen_len": int(long_gen_len)}
    idx = next((i for i, r in enumerate(trace)
                if r["arrival_step"] > long_arrival), len(trace))
    trace.insert(idx, req)
    return trace


def simulate_paged(trace, *, slots: int, max_len: int, block_size: int,
                   n_blocks: int, chunk: int) -> dict:
    """Pure-host mirror of the PAGED engine's scheduling AND block
    accounting: FIFO admission reserves ``ceil((P+1)/block_size)`` blocks
    up front (deferring the WHOLE queue when the pool can't cover the
    head — the engine's head-of-line policy), the prompt streams in one
    ``chunk`` per tick with the FINAL chunk sampling the first token
    (the row joins decode the same tick), each decode tick grows the
    active rows' block coverage to their write frontier, and retirement
    frees a row's blocks. ``run_paged`` asserts every counter — and the
    peak block occupancy — against the real engine.

    Does NOT model reclaim-by-preemption: the committed trace must fit
    ``n_blocks`` (a pool too small raises, rather than silently
    diverging from the engine's victim policy)."""
    if max_len % block_size:
        raise ValueError(f"max_len={max_len} % block_size={block_size}")
    max_blocks = max_len // block_size
    if n_blocks < max_blocks:
        raise ValueError(f"n_blocks={n_blocks} < max_blocks={max_blocks}")
    from collections import deque
    queue: deque = deque()
    rows = [None] * slots
    i, step = 0, 0
    decode_steps = prefills = generated = slot_steps = 0
    free, used, peak_used = n_blocks, 0, 0
    resident_block_steps = deferral_ticks = 0
    n = len(trace)

    def blocks_for(upto):
        return -(-upto // block_size)

    def retire(j):
        nonlocal free, used
        free += rows[j]["blocks"]
        used -= rows[j]["blocks"]
        rows[j] = None

    def has_work():
        return bool(queue) or any(r is not None for r in rows)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            queue.append(trace[i])
            i += 1
        for j in range(slots):
            if rows[j] is None and queue:
                r = queue[0]
                need = blocks_for(r["prompt_len"] + 1)
                if free < need:
                    deferral_ticks += 1
                    break       # head-of-line: the engine stops admitting
                queue.popleft()
                free -= need
                used += need
                peak_used = max(peak_used, used)
                rows[j] = {"p": r["prompt_len"], "budget": r["gen_len"],
                           "chunk_next": 0, "prefilling": True,
                           "pos": 0, "blocks": need, "emitted": 0}
        # One prompt chunk per admitting slot; the FINAL chunk samples
        # the first token and the row joins decode THIS tick.
        for j in range(slots):
            s = rows[j]
            if s is None or not s["prefilling"]:
                continue
            if s["p"] - s["chunk_next"] <= chunk:
                s["prefilling"] = False
                s["pos"] = s["p"]
                prefills += 1
                generated += 1
                s["emitted"] = 1
                if s["emitted"] == s["budget"]:
                    retire(j)
            else:
                s["chunk_next"] += chunk
        active = [j for j in range(slots)
                  if rows[j] is not None and not rows[j]["prefilling"]]
        if active:
            for j in active:    # cover this tick's K/V write at pos
                s = rows[j]
                need = blocks_for(s["pos"] + 1)
                grow = need - s["blocks"]
                if grow > 0:
                    if free < grow:
                        raise RuntimeError(
                            "simulate_paged does not model reclaim "
                            "preemption — size n_blocks above the "
                            "trace's peak demand")
                    free -= grow
                    used += grow
                    s["blocks"] = need
                    peak_used = max(peak_used, used)
            decode_steps += 1
            slot_steps += len(active)
            for j in active:
                s = rows[j]
                generated += 1
                s["emitted"] += 1
                s["pos"] += 1
                if s["emitted"] == s["budget"]:
                    retire(j)
        resident_block_steps += used
        step += 1
    occ = slot_steps / (decode_steps * slots) if decode_steps else 0.0
    return {"steps": step, "decode_steps": decode_steps,
            "prefills": prefills, "generated_tokens": generated,
            "slot_steps": slot_steps, "mean_occupancy": occ,
            "peak_used_blocks": peak_used,
            "resident_block_steps": resident_block_steps,
            "mean_resident_blocks":
                resident_block_steps / step if step else 0.0,
            "deferral_ticks": deferral_ticks}


def paged_cache_bytes_model(mcfg, *, slots: int, max_len: int,
                            block_size: int, n_blocks: int,
                            peak_used_blocks: int,
                            mean_resident_blocks: float) -> dict:
    """ANALYTIC K/V HBM residency of the paged cache vs the rectangular
    one, priced from ``cache_shapes`` (pure shape arithmetic — machine-
    independent, transfers to TPU):

      - ``rect_kv_bytes``: the rectangular engine pins ``slots *
        max_len`` K/V positions for its whole lifetime, long tenant or
        not;
      - ``pool_kv_bytes``: the paged pool's allocation (``n_blocks``
        blocks + the int32 block table) — the engine sizes it to the
        traffic, under the rectangular reservation;
      - ``peak_resident_bytes``: blocks the committed long-context trace
        ACTUALLY touches at its worst tick (``simulate_paged``'s peak,
        asserted against the real engine's ``pool_stats``).

    ``scripts/check_bench_drift.py`` re-prices this and fails when paged
    residency stops beating the rectangular reservation."""
    from repro.models import cache_shapes

    def kv_bytes(shapes):
        return sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                   for layer in shapes["stack"].values()
                   for key, s in layer.items() if key in ("k", "v"))

    paged = cache_shapes(mcfg, slots, max_len, row_lens=True,
                         block_size=block_size, n_blocks=n_blocks)
    rect = cache_shapes(mcfg, slots, max_len, row_lens=True)
    bytes_per_block = kv_bytes(paged) // n_blocks
    table_bytes = int(np.prod(paged["pages"].shape)) * 4
    rect_kv_bytes = kv_bytes(rect)
    pool_kv_bytes = bytes_per_block * n_blocks + table_bytes
    peak_resident = bytes_per_block * peak_used_blocks + table_bytes
    return {"arch": mcfg.name, "slots": slots, "max_len": max_len,
            "block_size": block_size, "n_blocks": n_blocks,
            "max_blocks": max_len // block_size,
            "rect_blocks": slots * (max_len // block_size),
            "bytes_per_block": bytes_per_block,
            "table_bytes": table_bytes,
            "rect_kv_bytes": rect_kv_bytes,
            "pool_kv_bytes": pool_kv_bytes,
            "peak_resident_bytes": peak_resident,
            "mean_resident_bytes":
                bytes_per_block * mean_resident_blocks + table_bytes,
            "rect_over_paged_pool": rect_kv_bytes / pool_kv_bytes,
            "rect_over_paged_peak": rect_kv_bytes / peak_resident}


def run_paged(arch="qwen2-7b", *, smoke=True, rank=64, slots=4,
              verbose=True) -> dict:
    """Block-paged engine + chunked prefill on the LONG-CONTEXT trace
    (the committed short-request trace plus one 48-token prompt).
    Deterministic and gated twice over, like ``run_continuous``:

      - the schedule/occupancy/block model (``simulate_paged``) must
        reproduce the real paged engine's counters AND pool stats
        exactly (asserted here);
      - the committed memory model (``paged_cache_bytes_model``) must
        keep paged residency strictly under the rectangular
        ``slots * max_len`` reservation (gated in
        ``scripts/check_bench_drift.py``, ``check_paged``).

    Measured tok/s stays informational (CPU wall-clock)."""
    from repro.launch.engine import DecodeEngine

    trace_params = {"n_requests": 12, "mean_interarrival": 2.0,
                    "prompt_len": 8, "gen_lens": (4, 6, 8, 10), "seed": 0}
    paged_params = {"slots": slots, "max_len": 64, "block_size": 8,
                    "n_blocks": 20, "prefill_chunk": 8,
                    "long_arrival": 2, "long_prompt_len": 48,
                    "long_gen_len": 6}
    trace = make_longcontext_trace(
        trace_params, long_arrival=paged_params["long_arrival"],
        long_prompt_len=paged_params["long_prompt_len"],
        long_gen_len=paged_params["long_gen_len"])
    max_len = paged_params["max_len"]
    sim = simulate_paged(trace, slots=slots, max_len=max_len,
                         block_size=paged_params["block_size"],
                         n_blocks=paged_params["n_blocks"],
                         chunk=paged_params["prefill_chunk"])

    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, r["prompt_len"],
                            dtype=np.int32) for r in trace]
    gen_lens = [r["gen_len"] for r in trace]

    engine = DecodeEngine(mcfg, scfg, params, slots=slots,
                          max_len=max_len, adapters=folded, paged=True,
                          block_size=paged_params["block_size"],
                          n_blocks=paged_params["n_blocks"],
                          prefill_chunk=paged_params["prefill_chunk"])
    _drive_engine(engine, trace, prompts, gen_lens)
    st = engine.stats()
    for field in ("steps", "decode_steps", "prefills",
                  "generated_tokens", "slot_steps"):
        got = getattr(st, field)
        want = sim[field]
        assert got == want, (
            f"paged engine {field}={got} but the committed scheduling "
            f"model says {want} — simulate_paged no longer mirrors the "
            f"engine; fix one of them before regenerating the artifact")
    ps = engine.pool_stats()
    assert ps["peak_used_blocks"] == sim["peak_used_blocks"], (
        f"engine peak {ps['peak_used_blocks']} blocks != model "
        f"{sim['peak_used_blocks']} — the block accounting in "
        f"simulate_paged no longer mirrors the engine's pool")
    assert ps["used_blocks"] == 0, f"blocks leaked after drain: {ps}"
    t0 = time.perf_counter()
    _drive_engine(engine, trace, prompts, gen_lens)
    dt = time.perf_counter() - t0

    model = paged_cache_bytes_model(
        mcfg, slots=slots, max_len=max_len,
        block_size=paged_params["block_size"],
        n_blocks=paged_params["n_blocks"],
        peak_used_blocks=sim["peak_used_blocks"],
        mean_resident_blocks=sim["mean_resident_blocks"])
    out = {"trace": dict(trace_params, **paged_params,
                         gen_lens=list(trace_params["gen_lens"])),
           "schedule_model": sim,
           "memory_model": model,
           "measured": {"engine_tok_s": sim["generated_tokens"] / dt}}
    if verbose:
        print(f"  paged: {sim['decode_steps']} decode steps over "
              f"{sim['steps']} ticks, occupancy "
              f"{sim['mean_occupancy']:.2f} "
              f"(long P={paged_params['long_prompt_len']} admitted in "
              f"{-(-paged_params['long_prompt_len'] // paged_params['prefill_chunk'])} chunks)")
        print(f"  blocks: peak {sim['peak_used_blocks']}/"
              f"{paged_params['n_blocks']} used (rectangular pins "
              f"{model['rect_blocks']}); resident bytes peak "
              f"{model['peak_resident_bytes']} vs rect "
              f"{model['rect_kv_bytes']} "
              f"({model['rect_over_paged_peak']:.2f}x smaller)")
        print(f"  model == engine counters + pool stats: OK; "
              f"{out['measured']['engine_tok_s']:.1f} tok/s (measured)")
    save("serve_bench_paged", [out])
    return out


# ---------------------------------------------------------------------------
# Fleet serving (traced dynamic grouping + tiered adapter cache).
# ---------------------------------------------------------------------------

def make_fleet_trace(*, n_requests=12, tenants=5, mean_interarrival=2.0,
                     prompt_len=8, gen_lens=(4, 6, 8, 10), seed=0):
    """The committed arrival trace with a per-request TENANT drawn from
    a second deterministic stream: N adapters ≫ slots, so the slot
    table's adapter layout churns on almost every admission.
    ``scripts/check_bench_drift.py`` rebuilds the trace from the
    committed parameters (``check_fleet``)."""
    trace = make_arrival_trace(n_requests=n_requests,
                               mean_interarrival=mean_interarrival,
                               prompt_len=prompt_len, gen_lens=gen_lens,
                               seed=seed)
    rng = np.random.default_rng(seed + 1)
    for r in trace:
        r["tenant"] = int(rng.integers(tenants))
    return trace


def simulate_fleet(trace, *, slots: int) -> dict:
    """:func:`simulate_continuous` extended with the slot table's TENANT
    layout, mirroring the engine's static signature rule
    (``DecodeEngine._slot_grouping``): free slots are absorbed into a
    neighbouring run, occupied slots collapse to run-length
    ``(start, size)`` blocks, and a single distinct tenant is the
    ``None`` signature. The STATIC engine compiles one decode executable
    per distinct signature the trace visits; the DYNAMIC engine compiles
    exactly ONE (``"dynamic"``) regardless of the tenant mix —
    ``run_fleet`` asserts BOTH counts against the real engines, and
    ``check_fleet`` re-simulates them from the committed trace."""
    from collections import deque
    queue: deque = deque()
    table = [None] * slots      # [remaining tokens, tenant] per busy slot
    i, step = 0, 0
    decode_steps = prefills = generated = slot_steps = 0
    signatures: set = set()
    n = len(trace)

    def has_work():
        return bool(queue) or any(v is not None for v in table)

    def signature():
        # the engine's rule: forward fill from the left, then leading
        # Nones from the right; one distinct tenant -> None; else
        # run-length (start, size) blocks.
        keys = [(table[j][1] if table[j] is not None else None)
                for j in range(slots)]
        last = None
        for j, k in enumerate(keys):
            if k is None:
                keys[j] = last
            else:
                last = k
        nxt = None
        for j in reversed(range(slots)):
            if keys[j] is None:
                keys[j] = nxt
            else:
                nxt = keys[j]
        if len(set(keys)) == 1:
            return None
        runs: list = []
        for k in keys:
            if runs and runs[-1][0] == k:
                runs[-1] = (k, runs[-1][1] + 1)
            else:
                runs.append((k, 1))
        groups, start = [], 0
        for _, cnt in runs:
            groups.append((start, cnt))
            start += cnt
        return tuple(groups)

    while i < n or has_work():
        while i < n and trace[i]["arrival_step"] <= step:
            queue.append(trace[i])
            i += 1
        for j in range(slots):
            while table[j] is None and queue:
                r = queue.popleft()
                prefills += 1
                generated += 1                  # first token from prefill
                if r["gen_len"] - 1 > 0:
                    table[j] = [r["gen_len"] - 1, r["tenant"]]
        active = [j for j in range(slots) if table[j] is not None]
        if active:
            signatures.add(signature())
            decode_steps += 1
            slot_steps += len(active)
            for j in active:
                generated += 1
                table[j][0] -= 1
                if table[j][0] == 0:
                    table[j] = None
        step += 1
    occ = slot_steps / (decode_steps * slots) if decode_steps else 0.0
    return {"steps": step, "decode_steps": decode_steps,
            "prefills": prefills, "generated_tokens": generated,
            "slot_steps": slot_steps, "mean_occupancy": occ,
            "static_signatures": len(signatures),
            "signature_keys": sorted(str(s) for s in signatures),
            "dynamic_signatures": 1}


def fleet_admission_bytes_model(d_out: int, d_in: int, rank: int,
                                dtype_size: int = 4) -> dict:
    """ANALYTIC per-adapted-layer admission cost of the two not-resident
    tenant kinds, in bytes moved (machine-independent, transfers to
    TPU):

      - ``cold``: a registered-but-dropped (or never-precomputed)
        adapter pays the full precompute — the factored norm READS
        W [d_out, d_in] + A + B + m, then WRITES the serving state
        (A + gsB + g, with |gsB| == |B|);
      - ``spilled``: the state already exists in the host tier —
        admission is ONE host→device copy of the state bytes; no W
        read, no norm arithmetic. A spilled tenant therefore costs
        queue latency only, never an ``AdapterCacheMiss``.

    Gated in ``scripts/check_bench_drift.py`` (``check_fleet``): spilled
    admission must stay strictly cheaper than cold."""
    a = rank * d_in * dtype_size
    b = d_out * rank * dtype_size
    vec = d_out * dtype_size          # m / g row vectors (fp32)
    w = d_out * d_in * dtype_size
    state = a + b + vec               # A + gsB + g
    cold = (w + a + b + vec) + state  # norm reads + state write
    return {"d_out": d_out, "d_in": d_in, "rank": rank,
            "dtype_size": dtype_size,
            "state_bytes": state,
            "cold_admission_bytes": cold,
            "spilled_admission_bytes": state,
            "model_ratio_cold_over_spilled": cold / state}


def _drive_fleet(engine, trace, prompts):
    """:func:`_drive_engine` with per-request adapter routing."""
    i, step = 0, 0
    while i < len(trace) or engine.has_work():
        while i < len(trace) and trace[i]["arrival_step"] <= step:
            engine.submit(prompts[i],
                          adapter=f"tenant-{trace[i]['tenant']}",
                          max_new_tokens=trace[i]["gen_len"])
            i += 1
        engine.step()
        step += 1


def run_fleet(arch="qwen2-7b", *, smoke=True, rank=64, slots=3, tenants=5,
              verbose=True) -> dict:
    """Fleet serving on the committed churny multi-tenant trace.
    Deterministic and gated three ways (``check_fleet``):

      - the schedule + SIGNATURE model (``simulate_fleet``) must
        reproduce the real engines' counters, the static engine's
        decode-executable count, and the dynamic engine's constant ONE
        (asserted here against both real engines);
      - the dynamic engine's greedy streams are asserted bitwise
        identical to the static engine's (the tentpole's oracle);
      - the admission model (``fleet_admission_bytes_model``) must keep
        a spilled tenant strictly cheaper to admit than a cold one
        (measured cold-precompute vs host-reload wall times stay
        informational)."""
    from repro.launch.engine import DecodeEngine

    trace_params = {"n_requests": 12, "tenants": tenants,
                    "mean_interarrival": 2.0, "prompt_len": 8,
                    "gen_lens": (4, 6, 8, 10), "seed": 0}
    trace = make_fleet_trace(**trace_params)
    max_len = trace_params["prompt_len"] + max(trace_params["gen_lens"])
    sim = simulate_fleet(trace, slots=slots)

    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, _, _ = build_state(mcfg, dcfg, 0)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, r["prompt_len"],
                            dtype=np.int32) for r in trace]

    def perturbed(ad, seed):
        # distinct non-zero B per tenant: seed-built B is 0, and the
        # bitwise dynamic-vs-static oracle needs tenants to differ.
        key = jax.random.PRNGKey(seed)
        cnt = [0]

        def go(path, leaf):
            cnt[0] += 1
            if "'B'" in "/".join(str(p) for p in path):
                return 0.1 * jax.random.normal(
                    jax.random.fold_in(key, cnt[0]), leaf.shape,
                    leaf.dtype)
            return leaf

        return jax.tree_util.tree_map_with_path(go, ad)

    def fleet_cache():
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        for t in range(tenants):
            _, ad_t, _ = build_state(mcfg, dcfg, 10 + t)
            cache.register(f"tenant-{t}", perturbed(ad_t, 100 + t))
        return cache

    dyn = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                       adapter_cache=fleet_cache(), dynamic_grouping=True)
    _drive_fleet(dyn, trace, prompts)
    st = dyn.stats()
    for field in ("decode_steps", "prefills", "generated_tokens",
                  "slot_steps"):
        got, want = getattr(st, field), sim[field]
        assert got == want, (
            f"dynamic engine {field}={got} but the committed scheduling "
            f"model says {want} — simulate_fleet no longer mirrors the "
            f"engine; fix one of them before regenerating the artifact")
    dyn_counts = dyn.compile_counts()
    assert dyn_counts["decode"] == {"dynamic": 1}, (
        "the dynamic engine compiled more than ONE decode executable "
        "over the churny fleet trace — tenant churn leaked into the "
        "compile signature", dyn_counts)
    assert dyn_counts["adapter_insert"] == 1, dyn_counts
    dyn_tokens = {r.request_id: r.tokens.tolist()
                  for r in dyn.pop_results()}

    static = DecodeEngine(mcfg, scfg, params, slots=slots,
                          max_len=max_len, adapter_cache=fleet_cache())
    _drive_fleet(static, trace, prompts)
    static_tokens = {r.request_id: r.tokens.tolist()
                     for r in static.pop_results()}
    assert dyn_tokens == static_tokens, (
        "dynamic-grouped streams diverged from the static engine — the "
        "bitwise oracle is broken", dyn_tokens, static_tokens)
    sta_counts = static.compile_counts()
    assert len(sta_counts["decode"]) == sim["static_signatures"], (
        f"static engine compiled {len(sta_counts['decode'])} decode "
        f"signatures but simulate_fleet predicts "
        f"{sim['static_signatures']} — the signature rule in "
        f"simulate_fleet no longer mirrors _slot_grouping")

    # timed second pass on the dynamic engine (compiles are warm)
    t0 = time.perf_counter()
    _drive_fleet(dyn, trace, prompts)
    dt = time.perf_counter() - t0
    dyn.pop_results()

    # measured cold-precompute vs spilled-reload admission on the
    # TIERED cache (informational — the gate prices the bytes model)
    tiered = AdapterStateCache.for_serving(mcfg, scfg)
    handles = []
    for t in range(2):
        _, ad_t, _ = build_state(mcfg, dcfg, 10 + t)
        handles.append(tiered.register(f"tier-{t}", ad_t))
    jax.block_until_ready(tiered.get_state(params, handles[0]))
    tiered.max_bytes = tiered.stats().current_bytes   # room for ONE state
    tiered.host_max_bytes = 10 * tiered.max_bytes     # spill tier on
    jax.block_until_ready(tiered.get_state(params, handles[1]))
    assert tiered.is_spilled(handles[0]), \
        "eviction under a host budget must SPILL, not drop"
    t0 = time.perf_counter()
    jax.block_until_ready(tiered.get_state(params, handles[0]))  # reload
    t_reload = time.perf_counter() - t0
    tiered.invalidate("tier-1")                       # cold in both tiers
    t0 = time.perf_counter()
    jax.block_until_ready(tiered.get_state(params, handles[1]))  # cold
    t_cold = time.perf_counter() - t0
    tstats = tiered.stats().as_dict()
    assert tstats["reloads"] >= 1 and tstats["spills"] >= 2, tstats

    model = fleet_admission_bytes_model(mcfg.d_model, mcfg.d_model, rank)
    out = {"trace": dict(trace_params, slots=slots, max_len=max_len,
                         gen_lens=list(trace_params["gen_lens"])),
           "schedule_model": sim,
           "admission_model": model,
           "measured": {"engine_tok_s": sim["generated_tokens"] / dt,
                        "cold_admission_ms": 1e3 * t_cold,
                        "spilled_reload_ms": 1e3 * t_reload,
                        "tiered_cache": tstats}}
    if verbose:
        print(f"  fleet: {trace_params['n_requests']} requests x "
              f"{tenants} tenants through {slots} slots — dynamic "
              f"compiled 1 decode executable, static needed "
              f"{sim['static_signatures']} "
              f"({sim['decode_steps']} decode steps, occupancy "
              f"{sim['mean_occupancy']:.2f})")
        print(f"  oracle: dynamic greedy streams == static (bitwise); "
              f"{out['measured']['engine_tok_s']:.1f} tok/s (measured)")
        print(f"  admission model: cold "
              f"{model['cold_admission_bytes']} B vs spilled "
              f"{model['spilled_admission_bytes']} B "
              f"({model['model_ratio_cold_over_spilled']:.1f}x); "
              f"measured cold {1e3 * t_cold:.1f} ms vs reload "
              f"{1e3 * t_reload:.1f} ms")
    save("serve_bench_fleet", [out])
    return out


def write_artifact(rows, multi_tenant=None, continuous=None,
                   speculative=None, paged=None, fleet=None, obs=None,
                   path="BENCH_serve.json") -> str:
    payload = {"bench": "serve_decode",
               "rows": rows,
               "notes": "smoke-config CPU decode; the cached/uncached "
                        "ratio isolates the per-token factored-norm work "
                        "removed by precompute_adapter_state. "
                        "multi_tenant: LRU-routed grouped decode "
                        "(cold-miss vs warm-hit); its 'model' section is "
                        "the analytic per-token adapter-path bytes gated "
                        "by scripts/check_bench_drift.py (mt_hit must "
                        "price identically to cached_gsb). continuous: "
                        "slot-scheduled engine vs static batches under "
                        "one arrival trace — the deterministic schedule "
                        "model (decode steps / occupancy) is gated "
                        "(engine must beat static); measured tok/s is "
                        "informational. speculative: draft/verify engine "
                        "vs plain decode on the same trace — the "
                        "accept-rate schedule model is gated (speculative "
                        "must need fewer full-DoRA verify steps than "
                        "plain decode emits tokens, at full AND degraded "
                        "accept rates). paged: block-paged engine + "
                        "chunked prefill on a long-context trace — the "
                        "schedule/block model is asserted against the "
                        "real engine and the memory model (peak resident "
                        "block bytes vs the rectangular slots*max_len "
                        "reservation) is gated (paged must stay strictly "
                        "under rectangular). fleet: traced dynamic "
                        "grouping vs static signatures on a churny "
                        "multi-tenant trace — the signature model (static "
                        "compiles one decode executable per distinct slot "
                        "layout, dynamic exactly ONE) and the admission "
                        "model (a spilled tenant admits strictly cheaper "
                        "than a cold one) are gated; wall times are "
                        "informational. obs: per-request lifecycle-tick "
                        "percentiles (queue wait / TTFT / admit-to-retire "
                        "/ occupancy) derived from a TraceRecorder on the "
                        "continuous trace and asserted equal to the "
                        "pure-host lifecycle model; check_obs hard-fails "
                        "if queue-wait p50 regresses; wall-domain "
                        "percentiles are informational."}
    if multi_tenant is not None:
        payload["multi_tenant"] = multi_tenant
    if continuous is not None:
        payload["continuous"] = continuous
    if speculative is not None:
        payload["speculative"] = speculative
    if paged is not None:
        payload["paged"] = paged
    if fleet is not None:
        payload["fleet"] = fleet
    if obs is not None:
        payload["obs"] = obs
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short decode, small batch (the MODEL "
                         "is always the smoke config on this CPU "
                         "container; rows record the actual arch name)")
    ap.add_argument("--artifact", default="",
                    help="also write the committed BENCH_serve.json")
    args, _ = ap.parse_known_args()
    gen = 8 if args.smoke else args.gen_len
    batch = 2 if args.smoke else args.batch
    print("# Decode tok/s before/after the frozen-adapter cache")
    rows = run(args.arch, smoke=True, rank=args.rank, batch=batch,
               gen_len=gen)
    print("# Multi-tenant: LRU cache cold-miss vs warm-hit vs single-tenant")
    mt = run_multitenant(args.arch, smoke=True, rank=args.rank,
                         gen_len=gen)
    print("# Continuous batching: slot-scheduled engine vs static batches")
    cont = run_continuous(args.arch, smoke=True, rank=args.rank)
    print("# Speculative decode: draft/verify vs plain on the same trace")
    spec = run_speculative(args.arch, smoke=True, rank=args.rank)
    print("# Paged KV cache: block pool + chunked prefill, long-context trace")
    pg = run_paged(args.arch, smoke=True, rank=args.rank)
    print("# Fleet: traced dynamic grouping vs static signatures, tiered cache")
    fl = run_fleet(args.arch, smoke=True, rank=args.rank)
    print("# Observability: lifecycle-tick percentiles, traced engine == model")
    ob = run_obs(args.arch, smoke=True, rank=args.rank)
    if args.artifact:
        print(f"wrote {os.path.abspath(write_artifact(rows, mt, cont, spec, pg, fl, ob, args.artifact))}")


if __name__ == "__main__":
    main()
