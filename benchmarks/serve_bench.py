"""Decode throughput before/after the frozen-adapter serving cache.

Measures the decode loop (the only part the cache touches per token) in
three configurations on a CPU-runnable smoke config:

  - ``uncached``   — the pre-tentpole path: the factored norm of every
    adapted layer recomputed on EVERY decode token;
  - ``cached``     — g precomputed once by ``precompute_adapter_state``,
    decode does zero norm work per token (bitwise-identical logits);
  - ``cached+gsB`` — g·s additionally folded into B (broadcast-free
    compose; allclose, not bitwise).

The multi-tenant section prices the request-routed server: ``mt-warm``
(every adapter state an LRU hit) and ``mt-cold`` (empty cache: the first
batch pays one precompute per tenant) against the single-tenant
``cached+gsB`` decode, plus the ANALYTIC per-token adapter-path bytes
model (``adapter_decode_bytes_model``) — where the cache-hit grouped path
prices IDENTICALLY to single-tenant cached decode by construction (each
row reads its own A/gsB/g once, no norm reads); the equality is gated in
``scripts/check_bench_drift.py``.

Absolute tok/s on this CPU is meaningless for TPU; the *ratio* isolates
exactly the per-token norm work the cache removes, and is recorded in the
committed ``BENCH_serve.json`` to seed the perf trajectory.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--artifact BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_precompute_step, make_prefill_step)
from repro.launch.train import build_state


def bench_decode(mcfg, scfg, params, adapters, *, batch, prompt_len,
                 max_len, gen_len, warmup=2, tenant_groups=None):
    """Time ``gen_len`` decode steps against a prefilled cache; returns
    (tok_s, ms_per_token). ``tenant_groups``: time the GROUPED multi-
    tenant decode step instead (same loop, adapter routing inside)."""
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, mcfg.vocab_size,
                                    (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=batch,
                                        seq=max_len,
                                        tenant_groups=tenant_groups))
    decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=batch,
                                      tenant_groups=tenant_groups))
    logits, cache = jax.block_until_ready(
        prefill(params, adapters, {"tokens": toks}))
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(warmup):
        logits, _ = decode(params, adapters, cache, {"tokens": nxt})
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    c = cache
    for _ in range(gen_len):
        logits, c = decode(params, adapters, c, {"tokens": nxt})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return batch * gen_len / dt, 1e3 * dt / gen_len


def run(arch="qwen2-7b", *, smoke=True, rank=64, batch=4, prompt_len=16,
        gen_len=32, verbose=True) -> list[dict]:
    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    max_len = prompt_len + gen_len + 4

    t0 = time.perf_counter()
    cached = jax.block_until_ready(jax.jit(
        make_precompute_step(mcfg, scfg))(params, adapters))
    t_pre = time.perf_counter() - t0
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))

    cases = [("uncached", adapters), ("cached", cached),
             ("cached+gsB", folded)]
    rows = []
    base_tok_s = None
    for name, tree in cases:
        tok_s, ms = bench_decode(mcfg, scfg, params, tree, batch=batch,
                                 prompt_len=prompt_len, max_len=max_len,
                                 gen_len=gen_len)
        base_tok_s = base_tok_s or tok_s
        row = {"mode": name, "arch": mcfg.name, "rank": rank,
               "batch": batch, "gen_len": gen_len,
               "tok_s": tok_s, "ms_per_token": ms,
               "speedup_vs_uncached": tok_s / base_tok_s}
        rows.append(row)
        if verbose:
            print(f"  {name:>12}: {tok_s:8.1f} tok/s  ({ms:6.2f} ms/tok, "
                  f"{row['speedup_vs_uncached']:.2f}x)")
    if verbose:
        print(f"  precompute (one-off, amortized over the adapter set): "
              f"{1e3 * t_pre:.1f} ms")
    for r in rows:
        r["precompute_ms"] = 1e3 * t_pre
    save("serve_bench", rows)
    return rows


# ---------------------------------------------------------------------------
# Multi-tenant serving (LRU adapter-state cache + grouped decode).
# ---------------------------------------------------------------------------

def adapter_decode_bytes_model(d_out: int, d_in: int, rank: int,
                               dtype_size: int = 4) -> dict:
    """ANALYTIC per-token, per-row, per-adapted-layer HBM reads of the
    ADAPTER path (the base y = x@Wᵀ is mode-independent and excluded):

      - ``uncached``: the factored norm re-reads W [d_out, d_in] (the
        base-squared term) + A + B + m every token, then the compose
        reads A + B + g again — the W read dominates;
      - ``cached``: A + B + the cached g (no W, no norm);
      - ``cached_gsb``: A + the folded gsB (same size as B) + g;
      - ``mt_hit``: the multi-tenant grouped path on a cache HIT — each
        row reads ITS OWN A[k]/gsB[k]/g[k] exactly once, so it prices
        IDENTICALLY to ``cached_gsb`` (gated: a multi-tenant design that
        priced worse than single-tenant cached decode would be a
        regression, not a feature).

    Pure integer arithmetic — machine-independent, transfers to TPU, and
    is the committed "model" section of BENCH_serve.json that
    ``scripts/check_bench_drift.py`` re-prices.
    """
    a = rank * d_in * dtype_size
    b = d_out * rank * dtype_size
    vec = d_out * dtype_size          # m / g / w_norm row vectors (fp32)
    w = d_out * d_in * dtype_size
    # uncached = the norm pass (W, A, B, m) PLUS the compose pass
    # (A, B, g) — A/B are read twice per token; the W read dominates.
    uncached = (w + a + b + vec) + (a + b + vec)
    cached = a + b + vec              # compose reads A, B + cached g
    cached_gsb = a + b + vec          # A + gsB (|gsB| == |B|) + g
    return {
        "d_out": d_out, "d_in": d_in, "rank": rank,
        "dtype_size": dtype_size,
        "uncached_bytes": uncached,
        "cached_bytes": cached,
        "cached_gsb_bytes": cached_gsb,
        "mt_hit_bytes": cached_gsb,   # identical pricing BY CONSTRUCTION
        "model_ratio_uncached_over_cached": uncached / cached,
    }


def run_multitenant(arch="qwen2-7b", *, smoke=True, rank=64, tenants=3,
                    rows_per=2, prompt_len=16, gen_len=32,
                    verbose=True) -> dict:
    """Cold-miss vs warm-hit multi-tenant serving vs single-tenant cached
    decode; returns {"rows": [...], "model": {...}, "cache": stats}.

    All three rows time the SAME decode loop (``bench_decode``), so the
    ratio isolates exactly the grouped adapter routing: warm-hit pays the
    per-row gsB gather, cold-miss additionally amortizes one LRU
    precompute per tenant over the batch's tokens."""
    mcfg = get_config(arch, smoke=smoke)
    dcfg = DoRAConfig(rank=rank, alpha=2.0 * rank, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    max_len = prompt_len + gen_len + 4
    B = tenants * rows_per
    rng = np.random.default_rng(0)

    cache = AdapterStateCache.for_serving(mcfg, scfg)
    handles = []
    for t in range(tenants):
        _, ad_t, _ = build_state(mcfg, dcfg, 10 + t)
        handles.append(cache.register(f"tenant-{t}", ad_t))

    # Single-tenant baseline: the SAME batch size, one adapter, folded
    # state — the tok/s the grouped cache-hit path must not fall behind.
    folded = jax.block_until_ready(jax.jit(make_precompute_step(
        mcfg, scfg, fold_gsb=True))(params, adapters))
    st_tok_s, st_ms = bench_decode(mcfg, scfg, params, folded, batch=B,
                                   prompt_len=prompt_len, max_len=max_len,
                                   gen_len=gen_len)

    # Warm-hit: every state an LRU hit; time the grouped decode loop.
    from repro.core import stack_adapter_states
    groups = tuple((t * rows_per, rows_per) for t in range(tenants))
    states = [cache.get_state(params, h) for h in handles]   # cold misses
    stacked = stack_adapter_states(states, axis=1)
    warm_tok_s, warm_ms = bench_decode(mcfg, scfg, params, stacked,
                                       batch=B, prompt_len=prompt_len,
                                       max_len=max_len, gen_len=gen_len,
                                       tenant_groups=groups)

    # Cold-miss: drop the cached states (registry intact) and re-derive
    # them through the LRU — the recompute cost amortized over this
    # batch's tokens is the miss penalty.
    cache.invalidate()
    t0 = time.perf_counter()
    states = [cache.get_state(params, h) for h in handles]
    stacked = jax.block_until_ready(
        stack_adapter_states(states, axis=1))
    t_miss = time.perf_counter() - t0
    dt_decode = B * gen_len / warm_tok_s
    cold_tok_s = B * gen_len / (dt_decode + t_miss)
    cold_ms = 1e3 * (dt_decode + t_miss) / gen_len

    rows = [
        {"mode": "single-tenant cached+gsB", "tok_s": st_tok_s,
         "ms_per_token": st_ms},
        {"mode": "mt-warm", "tok_s": warm_tok_s, "ms_per_token": warm_ms,
         "vs_single_tenant": warm_tok_s / st_tok_s},
        {"mode": "mt-cold", "tok_s": cold_tok_s, "ms_per_token": cold_ms,
         "vs_single_tenant": cold_tok_s / st_tok_s,
         "miss_precompute_ms": 1e3 * t_miss},
    ]
    for r in rows:
        r.update(arch=mcfg.name, rank=rank, tenants=tenants,
                 batch=B, gen_len=gen_len)
    model = adapter_decode_bytes_model(mcfg.d_model, mcfg.d_model, rank)
    stats = cache.stats().as_dict()
    if verbose:
        for r in rows:
            extra = (f" ({r['vs_single_tenant']:.2f}x vs single-tenant)"
                     if "vs_single_tenant" in r else "")
            print(f"  {r['mode']:>26}: {r['tok_s']:8.1f} tok/s "
                  f"({r['ms_per_token']:6.2f} ms/tok){extra}")
        print(f"  cache: {stats['hits']} hits / {stats['misses']} misses "
              f"/ {stats['current_bytes']} state bytes; analytic "
              f"mt_hit == cached_gsb: "
              f"{model['mt_hit_bytes'] == model['cached_gsb_bytes']}")
    save("serve_bench_multitenant", rows)
    return {"rows": rows, "model": model, "cache": stats}


def write_artifact(rows, multi_tenant=None, path="BENCH_serve.json") -> str:
    payload = {"bench": "serve_decode",
               "rows": rows,
               "notes": "smoke-config CPU decode; the cached/uncached "
                        "ratio isolates the per-token factored-norm work "
                        "removed by precompute_adapter_state. "
                        "multi_tenant: LRU-routed grouped decode "
                        "(cold-miss vs warm-hit); its 'model' section is "
                        "the analytic per-token adapter-path bytes gated "
                        "by scripts/check_bench_drift.py (mt_hit must "
                        "price identically to cached_gsb)."}
    if multi_tenant is not None:
        payload["multi_tenant"] = multi_tenant
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short decode, small batch (the MODEL "
                         "is always the smoke config on this CPU "
                         "container; rows record the actual arch name)")
    ap.add_argument("--artifact", default="",
                    help="also write the committed BENCH_serve.json")
    args, _ = ap.parse_known_args()
    gen = 8 if args.smoke else args.gen_len
    batch = 2 if args.smoke else args.batch
    print("# Decode tok/s before/after the frozen-adapter cache")
    rows = run(args.arch, smoke=True, rank=args.rank, batch=batch,
               gen_len=gen)
    print("# Multi-tenant: LRU cache cold-miss vs warm-hit vs single-tenant")
    mt = run_multitenant(args.arch, smoke=True, rank=args.rank,
                         gen_len=gen)
    if args.artifact:
        print(f"wrote {os.path.abspath(write_artifact(rows, mt, args.artifact))}")


if __name__ == "__main__":
    main()
