"""Norm memory benchmark — paper Tables 1 & 7 / Figure 9.

Compares the three norm implementations (PEFT identity-matrix, dense B@A,
factored) on the paper's shape grid: theoretical persistent working set,
compiled temp-allocation delta (the allocator-peak analogue), and HLO
bytes-accessed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, fmt_bytes, save
from repro.core import factored_norm as N

# Paper Table 7 grid: (d_out, d_in, rank).
GRID = [
    (4096, 4096, 64),
    (4096, 4096, 384),
    (4096, 4096, 512),
    (8192, 8192, 384),
    (8192, 8192, 512),
    (8192, 8192, 768),
    (4096, 11008, 384),
    (8192, 28672, 384),   # the MoE shape: paper's 11x measured win
]
S = 2.0


def theory_bytes(d_out, d_in, r, dtype_bytes=4):
    """Persistent working set (paper Table 1): PEFT = eye + dense product;
    factored = U + G."""
    peft = (d_in * d_in + d_out * d_in) * dtype_bytes
    dense = d_out * d_in * dtype_bytes
    factored = (d_out * r + r * r) * dtype_bytes
    return peft, dense, factored


def run(dtype=jnp.float32, verbose: bool = True) -> list[dict]:
    rows = []
    for d_out, d_in, r in GRID:
        W = jax.ShapeDtypeStruct((d_out, d_in), dtype)
        A = jax.ShapeDtypeStruct((r, d_in), dtype)
        B = jax.ShapeDtypeStruct((d_out, r), dtype)

        impls = {
            "peft_eye": functools.partial(N.norm_peft_eye, s=S),
            "dense_ba": functools.partial(N.norm_dense_ba, s=S),
            "factored": functools.partial(N.factored_norm, s=S,
                                          chunk_mb=256),
        }
        stats = {k: compiled_stats(fn, W, A, B) for k, fn in impls.items()}
        t_peft, t_dense, t_fact = theory_bytes(d_out, d_in, r)
        row = {
            "shape": f"{d_out}x{d_in}", "rank": r,
            "theory": {"peft": t_peft, "dense_ba": t_dense,
                       "factored": t_fact,
                       "reduction": t_peft / t_fact},
            "measured_temp": {k: v["temp_bytes"] for k, v in stats.items()},
            "bytes_accessed": {k: v["bytes_accessed"]
                               for k, v in stats.items()},
            "measured_reduction": (stats["peft_eye"]["temp_bytes"]
                                   / max(stats["factored"]["temp_bytes"],
                                         1)),
        }
        rows.append(row)
        if verbose:
            print(f"  {row['shape']:>12} r={r:<4} "
                  f"theory {fmt_bytes(t_peft):>8} -> "
                  f"{fmt_bytes(t_fact):>8} ({row['theory']['reduction']:5.1f}x) | "
                  f"temp {fmt_bytes(row['measured_temp']['peft_eye']):>8} -> "
                  f"{fmt_bytes(row['measured_temp']['factored']):>8} "
                  f"({row['measured_reduction']:4.1f}x)")
    save("norm_memory", rows)
    return rows


def main() -> None:
    print("# Norm memory (paper Tables 1/7): PEFT-eye vs dense-BA vs "
          "factored, fp32")
    run()


if __name__ == "__main__":
    main()
