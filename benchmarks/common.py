"""Shared benchmark utilities: wall-clock timing, compiled-artifact
accounting (the CPU-container analogue of the paper's CUDA-event timing +
allocator deltas).

Two measurement channels, mirroring the paper's methodology (App. D):

  - **wall**: median of N jitted calls (block_until_ready), warmup
    excluded — meaningful for *relative* comparisons on this CPU.
  - **compiled**: HLO-level flops / bytes-accessed / temp-allocation from
    ``.lower().compile()`` — hardware-independent, the number that
    transfers to TPU. Memory deltas (Tables 1/7) use
    ``memory_analysis().temp_size_in_bytes`` as the allocator-peak
    analogue.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax

from repro.compat import xla as cxla

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def time_fn(fn, *args, repeats: int = 20, warmup: int = 3) -> dict:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return {
        "median_s": statistics.median(times),
        "mean_s": statistics.fmean(times),
        "min_s": min(times),
        "repeats": repeats,
    }


def compiled_stats(fn, *args) -> dict:
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    cost = cxla.cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    return {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "temp_bytes": mem.temp_size_in_bytes,
        "peak_bytes": cxla.peak_memory_bytes(compiled),
        "argument_bytes": mem.argument_size_in_bytes,
    }


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"
