"""Rank scaling benchmark — paper Table 6 / Figure 10.

Sweeps DoRA rank on one adapted linear and records norm cost for the
three implementations. The paper's claim: PEFT's cost is constant in r
(it always materializes the dense product) while the factored path's
rank-dependent intermediates (U [d_out, r], G [r, r]) stay small, so the
speedup over PEFT *grows* with rank.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, fmt_bytes, save, time_fn
from repro.core import factored_norm as N

RANKS = [64, 128, 384, 512, 768]
D_OUT, D_IN = 2048, 2048
S = 2.0


def run(dtype=jnp.float32, verbose: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (D_OUT, D_IN), dtype)
    for r in RANKS:
        ka, kb = jax.random.split(jax.random.fold_in(key, r))
        A = jax.random.normal(ka, (r, D_IN), dtype) * 0.02
        B = jax.random.normal(kb, (D_OUT, r), dtype) * 0.02
        impls = {
            "peft_eye": functools.partial(N.norm_peft_eye, s=S),
            "dense_ba": functools.partial(N.norm_dense_ba, s=S),
            "factored": functools.partial(N.factored_norm, s=S,
                                          chunk_mb=256),
        }
        row = {"rank": r}
        for name, fn in impls.items():
            st = compiled_stats(fn, W, A, B)
            t = time_fn(jax.jit(fn), W, A, B, repeats=3, warmup=1)
            row[name] = {"flops": st["flops"],
                         "bytes": st["bytes_accessed"],
                         "temp": st["temp_bytes"],
                         "wall_s": t["median_s"]}
        row["wall_speedup_vs_peft"] = (row["peft_eye"]["wall_s"]
                                       / row["factored"]["wall_s"])
        rows.append(row)
        if verbose:
            print(f"  r={r:<4} factored {row['factored']['wall_s']*1e3:7.1f}ms"
                  f" temp {fmt_bytes(row['factored']['temp']):>8} | "
                  f"peft {row['peft_eye']['wall_s']*1e3:7.1f}ms temp "
                  f"{fmt_bytes(row['peft_eye']['temp']):>8} | "
                  f"speedup {row['wall_speedup_vs_peft']:.2f}x")
    save("rank_scaling", rows)
    return rows


def main() -> None:
    print(f"# Rank scaling (paper Table 6/Fig 10), {D_OUT}x{D_IN} fp32")
    run()


if __name__ == "__main__":
    main()
