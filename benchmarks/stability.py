"""Numerical stability benchmark — paper Figure 1.

Max-abs error of the naive form ``g*(s*lora+base) - base`` vs the stable
form ``(g-1)*base + g*s*lora`` against an fp64 reference, sweeping the
magnitude scale g through the near-unity regime where DoRA concentrates
(paper: mean ~1.0, std ~0.0015; 100% of g inside the bf16 collapse zone).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.core import compose as C

SHAPE = (2048, 8192)  # paper Fig. 1 shape
S = 2.0


def run(dtype=jnp.bfloat16, verbose: bool = True) -> list[dict]:
    jax.config.update("jax_enable_x64", True)  # genuine fp64 reference
    key = jax.random.PRNGKey(0)
    kb, kl = jax.random.split(key)
    base = jax.random.normal(kb, SHAPE, jnp.float32).astype(dtype)
    lora = (0.01 * jax.random.normal(kl, SHAPE, jnp.float32)).astype(dtype)

    rows = []
    # |g-1| sweep: from well inside the bf16 collapse zone (eps/2 ~ 3.9e-3)
    # to clearly outside.
    for delta in [1e-5, 1e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 5e-2, 1e-1]:
        g = jnp.full((SHAPE[1],), 1.0 + delta, jnp.float32)
        ref = C.compose_reference_fp64(base, lora, g, S)
        naive = C.compose_naive(base, lora, g, S).astype(jnp.float64)
        stable = C.compose_stable(base, lora, g, S).astype(jnp.float64)
        err_n = float(jnp.max(jnp.abs(naive - ref)))
        err_s = float(jnp.max(jnp.abs(stable - ref)))
        rows.append({"g_minus_1": delta, "naive_maxerr": err_n,
                     "stable_maxerr": err_s,
                     "ratio": err_n / max(err_s, 1e-30)})
        if verbose:
            print(f"  |g-1|={delta:8.0e}  naive {err_n:9.3e}  "
                  f"stable {err_s:9.3e}  ratio {rows[-1]['ratio']:6.1f}x")
    save("stability", rows)
    return rows


def collapse_zone_stats(dtype=jnp.bfloat16) -> dict:
    """Fraction of a realistic g distribution inside the dtype collapse
    zone |g-1| < eps/2 (paper §3.1: 100% for bf16, 20% for fp16)."""
    g = 1.0 + 0.0015 * np.random.default_rng(0).standard_normal(1_000_000)
    eps = float(jnp.finfo(dtype).eps)
    return {"dtype": str(jnp.dtype(dtype)),
            "frac_in_collapse_zone": float((np.abs(g - 1) < eps / 2).mean())}


def main() -> None:
    print("# Compose stability near g~1 (paper Fig. 1), bf16, shape "
          f"{SHAPE}")
    run()
    for dt in (jnp.bfloat16, jnp.float16):
        st = collapse_zone_stats(dt)
        print(f"  collapse zone ({st['dtype']}): "
              f"{100 * st['frac_in_collapse_zone']:.1f}% of g values")


if __name__ == "__main__":
    main()
