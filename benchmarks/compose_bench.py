"""Compose kernel benchmark — paper §5.4 / Table 9 / Figures 6-7.

The paper's claim is a memory-traffic one: eager DoRA compose = 4 kernel
launches x ~3 passes = ~12 HBM passes; fused = 1 pass (3 reads + 1 write).
On this CPU container we measure the two transferable quantities:

  - HLO bytes-accessed of the *un-fused* op sequence (forced with
    optimization barriers, reproducing the 4-launch eager schedule) vs.
    the single fused expression — the traffic ratio that bounds the TPU
    speedup;
  - wall-clock of the jitted eager path vs. the Pallas kernel in
    interpret mode for *correctness* only (interpret mode is not a
    performance proxy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, fmt_bytes, save, time_fn
from repro.core import compose as C
from repro.kernels import ops as K

SHAPES = [(1024, 2048), (4096, 4096), (8192, 4096), (16384, 8192)]
S = 2.0


def eager_unfused(base, lora, g, s):
    """The 4-op eager schedule with fusion barriers between ops — the HLO
    analogue of 4 separate CUDA kernel launches (paper §3.1)."""
    b = jax.lax.optimization_barrier(base.astype(jnp.float32))
    t = jax.lax.optimization_barrier(s * lora.astype(jnp.float32))
    u = jax.lax.optimization_barrier((g - 1.0) * b)
    v = jax.lax.optimization_barrier(g * t)
    return (u + v).astype(base.dtype)


def fused_expr(base, lora, g, s):
    """Single fused expression (XLA fuses the element-wise chain)."""
    return C.compose_stable(base, lora, g, s)


def run(dtype=jnp.bfloat16, verbose: bool = True) -> list[dict]:
    rows = []
    for m, n in SHAPES:
        key = jax.random.PRNGKey(0)
        kb, kl = jax.random.split(key)
        base = jax.random.normal(kb, (m, n), jnp.float32).astype(dtype)
        lora = jax.random.normal(kl, (m, n), jnp.float32).astype(dtype)
        g = 1.0 + 1e-3 * jax.random.normal(jax.random.PRNGKey(2), (n,),
                                           jnp.float32)

        st_eager = compiled_stats(
            lambda b, l, gg: eager_unfused(b, l, gg, S), base, lora, g)
        st_fused = compiled_stats(
            lambda b, l, gg: fused_expr(b, l, gg, S), base, lora, g)

        jf_eager = jax.jit(lambda b, l, gg: eager_unfused(b, l, gg, S))
        jf_fused = jax.jit(lambda b, l, gg: fused_expr(b, l, gg, S))
        t_eager = time_fn(jf_eager, base, lora, g, repeats=10)
        t_fused = time_fn(jf_fused, base, lora, g, repeats=10)

        # correctness of the Pallas kernel (interpret mode) vs eager
        out_k = K.fused_compose(base, lora, g, S, save_inner=False,
                                mag_grad=False, interpret=True)
        out_e = fused_expr(base, lora, g, S)
        maxerr = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                       - out_e.astype(jnp.float32))))

        traffic_ratio = (st_eager["bytes_accessed"]
                         / max(st_fused["bytes_accessed"], 1))
        row = {"shape": f"{m}x{n}",
               "bytes_eager": st_eager["bytes_accessed"],
               "bytes_fused": st_fused["bytes_accessed"],
               "traffic_ratio": traffic_ratio,
               "wall_eager_s": t_eager["median_s"],
               "wall_fused_s": t_fused["median_s"],
               "wall_speedup": t_eager["median_s"] / t_fused["median_s"],
               "kernel_vs_eager_maxerr": maxerr}
        rows.append(row)
        if verbose:
            print(f"  {row['shape']:>12}: traffic "
                  f"{fmt_bytes(row['bytes_eager']):>8} -> "
                  f"{fmt_bytes(row['bytes_fused']):>8} "
                  f"({traffic_ratio:.2f}x) | wall {row['wall_speedup']:.2f}x"
                  f" | kernel maxerr {maxerr:.2e}")
    save("compose_bench", rows)
    return rows


def main() -> None:
    print("# Compose traffic & wall (paper Table 9 / Fig 6-7), bf16")
    run()


if __name__ == "__main__":
    main()
