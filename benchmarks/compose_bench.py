"""Compose kernel benchmark — paper §5.4 / Table 9 / Figures 6-7, extended
with the matmul-fused compose.

The paper's claim is a memory-traffic one: eager DoRA compose = 4 kernel
launches x ~3 passes = ~12 HBM passes; fused = 1 pass (3 reads + 1 write).
On this CPU container we measure the two transferable quantities:

  - HLO bytes-accessed of the *un-fused* op sequence (forced with
    optimization barriers, reproducing the 4-launch eager schedule) vs.
    the single fused expression — the traffic ratio that bounds the TPU
    speedup;
  - wall-clock of the jitted eager path vs. the Pallas kernel in
    interpret mode for *correctness* only (interpret mode is not a
    performance proxy).

The matmul-fused section goes one fusion deeper: the unfused schedule
materializes ``y_lora = h@Bᵀ`` in HBM before the compose; the fused kernel
computes the up-projection per-tile in VMEM, so the [M, d_out] tensor is
never written or re-read. For that kernel the analytic bytes-moved model
(base read + delta write + h read + per-row-tile B re-reads) is reported
alongside the measured HLO bytes of the unfused schedule — the model is
the number that transfers to TPU.

Results land in results/bench/ and, via ``write_artifact``, in the
committed ``BENCH_compose.json`` that seeds the repo's perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, fmt_bytes, save, time_fn
from repro.core import DoRAConfig
from repro.core import compose as C
from repro.kernels import ops as K
from repro.kernels import ref as R

SHAPES = [(1024, 2048), (4096, 4096), (8192, 4096), (16384, 8192)]
# (rows, d_out, rank) for the matmul-fused path — r=384 is the paper's
# high-rank regime; 128 the padding floor; the 8-row entry is the
# decode-shaped grid (small M), priced at the shrunken block_m the
# config derives for it (DoRAConfig.resolve_mm_block_rows).
MM_SHAPES = [(1024, 2048, 128), (4096, 4096, 384), (8192, 4096, 384),
             (8, 4096, 64)]
SMOKE_SHAPES = [(256, 512)]
SMOKE_MM_SHAPES = [(256, 512, 64)]
S = 2.0
DTYPE_SIZE = 2  # bf16 — the dtype every section benches in


def eager_unfused(base, lora, g, s):
    """The 4-op eager schedule with fusion barriers between ops — the HLO
    analogue of 4 separate CUDA kernel launches (paper §3.1)."""
    b = jax.lax.optimization_barrier(base.astype(jnp.float32))
    t = jax.lax.optimization_barrier(s * lora.astype(jnp.float32))
    u = jax.lax.optimization_barrier((g - 1.0) * b)
    v = jax.lax.optimization_barrier(g * t)
    return (u + v).astype(base.dtype)


def fused_expr(base, lora, g, s):
    """Single fused expression (XLA fuses the element-wise chain)."""
    return C.compose_stable(base, lora, g, s)


def mm_unfused(base, h, B, g, s):
    """The pre-tentpole hot path: y_lora materialized in HBM (barrier),
    then the element-wise compose — what dispatch ran before the
    matmul-fused plan flag."""
    y_lora = jax.lax.optimization_barrier(h @ B.T)
    return C.compose_stable(base, y_lora, g, s)


def mm_fused_expr(base, h, B, g, s):
    """Single expression from the factored operands (XLA free to fuse the
    element-wise tail into the matmul, but the [M, N] product still exists
    as a buffer — the Pallas kernel is what removes it)."""
    g32 = g.astype(jnp.float32)
    t = jnp.asarray(s, jnp.float32) * jax.lax.dot_general(
        h, B, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return ((g32 - 1.0) * base.astype(jnp.float32)
            + g32 * t).astype(base.dtype)


def mm_kernel_bytes_model(m, n, r, dtype_size: int,
                          block_m: int | None = None) -> dict:
    """Analytic HBM traffic of the matmul-fused kernel vs the y_lora path.

    unfused: h read + B read + y_lora write + (base read + y_lora read +
             delta write)  →  4 full [M, N] passes + the small operands.
    fused:   base read + delta write (2 passes) + h read + B re-read once
             per row tile (the crossover term the dispatch guard bounds).
    The fused kernel moves the 128-lane-PADDED rank (rp), same as the
    dispatch guard — charging the raw r would understate the h/B terms
    for off-lane ranks. Rows are charged PADDED to the row tile, which is
    what the kernel actually computes; ``block_m=None`` derives the
    decode-aware tile from the config (small M shrinks the grid, so a
    2-row decode is priced at 8 padded rows, not 256).
    """
    if block_m is None:
        block_m = DoRAConfig().resolve_mm_block_rows(m)
    row_tiles = -(-m // block_m)
    mp = row_tiles * block_m
    mn = m * n * dtype_size
    mpn = mp * n * dtype_size
    rp = (r + 127) // 128 * 128
    unfused = 4 * mn + (m * r + n * r) * dtype_size + 4 * n
    fused = 2 * mpn + (mp * rp + row_tiles * n * rp) * dtype_size + 4 * n
    return {"bytes_unfused_model": unfused, "bytes_fused_model": fused,
            "model_ratio": unfused / fused}


def run_mm(dtype=jnp.bfloat16, shapes=None, verbose: bool = True,
           repeats: int = 10) -> list[dict]:
    """Matmul-fused compose: measured unfused HLO bytes + wall vs the
    fused expression, the analytic kernel bytes model, and interpret-mode
    kernel correctness vs the fp64 oracle."""
    rows = []
    for m, n, r in (shapes or MM_SHAPES):
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
        base = jax.random.normal(k1, (m, n), jnp.float32).astype(dtype)
        h = (0.3 * jax.random.normal(k2, (m, r), jnp.float32)).astype(dtype)
        B = (0.3 * jax.random.normal(k3, (n, r), jnp.float32)).astype(dtype)
        g = 1.0 + 1e-3 * jax.random.normal(k4, (n,), jnp.float32)

        st_unf = compiled_stats(
            lambda b, hh, bb, gg: mm_unfused(b, hh, bb, gg, S),
            base, h, B, g)
        st_fus = compiled_stats(
            lambda b, hh, bb, gg: mm_fused_expr(b, hh, bb, gg, S),
            base, h, B, g)
        jf_unf = jax.jit(lambda b, hh, bb, gg: mm_unfused(b, hh, bb, gg, S))
        jf_fus = jax.jit(
            lambda b, hh, bb, gg: mm_fused_expr(b, hh, bb, gg, S))
        t_unf = time_fn(jf_unf, base, h, B, g, repeats=repeats)
        t_fus = time_fn(jf_fus, base, h, B, g, repeats=repeats)

        # interpret-mode kernel correctness vs the fp32 dense oracle
        # (small slices keep the interpreter tractable at bench shapes;
        # the fp64-oracle bounds live in tests/test_compose_mm.py where
        # x64 is enabled).
        ms, ns = min(m, 512), min(n, 1024)
        out_k = K.fused_compose_mm(base[:ms, :ns], h[:ms], B[:ns], g[:ns],
                                   S, mag_grad=False, interpret=True)
        want = R.ref_compose_mm(base[:ms, :ns], h[:ms], B[:ns], g[:ns], S)
        maxerr = float(jnp.max(jnp.abs(
            out_k.astype(jnp.float32) - want.astype(jnp.float32))))

        model = mm_kernel_bytes_model(m, n, r, jnp.dtype(dtype).itemsize)
        row = {"shape": f"{m}x{n}r{r}",
               "bytes_unfused": st_unf["bytes_accessed"],
               "bytes_xla_fused": st_fus["bytes_accessed"],
               **model,
               "wall_unfused_s": t_unf["median_s"],
               "wall_xla_fused_s": t_fus["median_s"],
               "wall_speedup": t_unf["median_s"] / t_fus["median_s"],
               "kernel_vs_oracle_maxerr": maxerr}
        rows.append(row)
        if verbose:
            print(f"  {row['shape']:>14}: model "
                  f"{fmt_bytes(model['bytes_unfused_model']):>8} -> "
                  f"{fmt_bytes(model['bytes_fused_model']):>8} "
                  f"({model['model_ratio']:.2f}x) | measured unfused "
                  f"{fmt_bytes(row['bytes_unfused']):>8} | wall "
                  f"{row['wall_speedup']:.2f}x | maxerr {maxerr:.2e}")
    save("compose_mm_bench", rows)
    return rows


def write_artifact(rows_ew, rows_mm, path="BENCH_compose.json") -> str:
    """Commit-able perf artifact: the bytes-moved reduction both compose
    fusions deliver, seeding the repo's perf trajectory."""
    payload = {
        "bench": "compose",
        "dtype": "bfloat16",
        "elementwise_fused": rows_ew,
        "matmul_fused": rows_mm,
        "notes": "bytes_*_model are the analytic HBM-traffic numbers that "
                 "transfer to TPU; wall clocks are CPU-relative only.",
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    return path


def run(dtype=jnp.bfloat16, shapes=None, verbose: bool = True) -> list[dict]:
    rows = []
    for m, n in (shapes or SHAPES):
        key = jax.random.PRNGKey(0)
        kb, kl = jax.random.split(key)
        base = jax.random.normal(kb, (m, n), jnp.float32).astype(dtype)
        lora = jax.random.normal(kl, (m, n), jnp.float32).astype(dtype)
        g = 1.0 + 1e-3 * jax.random.normal(jax.random.PRNGKey(2), (n,),
                                           jnp.float32)

        st_eager = compiled_stats(
            lambda b, l, gg: eager_unfused(b, l, gg, S), base, lora, g)
        st_fused = compiled_stats(
            lambda b, l, gg: fused_expr(b, l, gg, S), base, lora, g)

        jf_eager = jax.jit(lambda b, l, gg: eager_unfused(b, l, gg, S))
        jf_fused = jax.jit(lambda b, l, gg: fused_expr(b, l, gg, S))
        t_eager = time_fn(jf_eager, base, lora, g, repeats=10)
        t_fused = time_fn(jf_fused, base, lora, g, repeats=10)

        # correctness of the Pallas kernel (interpret mode) vs eager
        out_k = K.fused_compose(base, lora, g, S, save_inner=False,
                                mag_grad=False, interpret=True)
        out_e = fused_expr(base, lora, g, S)
        maxerr = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                       - out_e.astype(jnp.float32))))

        traffic_ratio = (st_eager["bytes_accessed"]
                         / max(st_fused["bytes_accessed"], 1))
        row = {"shape": f"{m}x{n}",
               "bytes_eager": st_eager["bytes_accessed"],
               "bytes_fused": st_fused["bytes_accessed"],
               "traffic_ratio": traffic_ratio,
               "wall_eager_s": t_eager["median_s"],
               "wall_fused_s": t_fused["median_s"],
               "wall_speedup": t_eager["median_s"] / t_fused["median_s"],
               "kernel_vs_eager_maxerr": maxerr}
        rows.append(row)
        if verbose:
            print(f"  {row['shape']:>12}: traffic "
                  f"{fmt_bytes(row['bytes_eager']):>8} -> "
                  f"{fmt_bytes(row['bytes_fused']):>8} "
                  f"({traffic_ratio:.2f}x) | wall {row['wall_speedup']:.2f}x"
                  f" | kernel maxerr {maxerr:.2e}")
    save("compose_bench", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few repeats (CI gate)")
    ap.add_argument("--artifact", default="",
                    help="also write the committed BENCH_compose.json "
                         "artifact to this path")
    # parse_known_args: benchmarks.run invokes main() under its own argv.
    args, _ = ap.parse_known_args()
    print("# Compose traffic & wall (paper Table 9 / Fig 6-7), bf16")
    rows_ew = run(shapes=SMOKE_SHAPES if args.smoke else None)
    print("# Matmul-fused compose (y_lora never materialized), bf16")
    rows_mm = run_mm(shapes=SMOKE_MM_SHAPES if args.smoke else None,
                     repeats=3 if args.smoke else 10)
    if args.artifact:
        path = write_artifact(rows_ew, rows_mm, args.artifact)
        print(f"wrote {os.path.abspath(path)}")


if __name__ == "__main__":
    main()
