"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--skip NAME]

Table map (paper -> module):
    Table 1/7, Fig 9   norm_memory     norm working-set / allocator deltas
    Fig 1              stability       stable vs naive compose error
    Table 9, Fig 6/7   compose_bench   fused-compose traffic + wall
    Table 6, Fig 10    rank_scaling    norm cost vs rank
    Table 4/5/8        model_level     model-level train/infer configs
    Fig 5              dense_ba        dense-BA position in the gap
    (ours) §Roofline   roofline_run    dry-run roofline aggregation
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (compose_bench, dense_ba, model_level, norm_memory,
                        rank_scaling, roofline_run, stability)
from repro.obs import monotonic

SUITES = [
    ("norm_memory", norm_memory.main),
    ("stability", stability.main),
    ("compose_bench", compose_bench.main),
    ("rank_scaling", rank_scaling.main),
    ("model_level", model_level.main),
    ("dense_ba", dense_ba.main),
    ("roofline_run", roofline_run.main),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip", action="append", default=[])
    args = ap.parse_args()

    failures = []
    for name, fn in SUITES:
        if args.only and name != args.only:
            continue
        if name in args.skip:
            continue
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = monotonic()
        try:
            fn()
            print(f"=== {name} done in {monotonic() - t0:.1f}s")
        except Exception:  # noqa: BLE001 — benchmark isolation
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nall benchmark suites completed")


if __name__ == "__main__":
    main()
