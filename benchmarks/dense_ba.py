"""Dense-BA position benchmark — paper §5.3 / Figure 5.

Places the "obvious fix" (dense B@A, no identity matrix) inside the
PEFT -> factored gap: position = (t_peft - t_dense) / (t_peft - t_factored),
0% = no better than PEFT, 100% = as good as factored. The paper's finding
is that dense-BA's position is inconsistent across hardware (sometimes
negative); the factored norm is the robust fix. We measure the position on
this host at module level across the paper's shape grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import save, time_fn
from repro.core import factored_norm as N

# Wall-clock grid (executed, not just compiled — the MoE 8192x28672 shape
# stays in norm_memory where it is compile-only; its 3.3 GB eye would take
# minutes per trial on one CPU core).
GRID = [(2048, 2048, 384), (4096, 4096, 384), (4096, 11008, 384)]
S = 2.0


def run(dtype=jnp.float32, verbose: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for d_out, d_in, r in GRID:
        kw, ka, kb = jax.random.split(jax.random.fold_in(key, d_out * d_in),
                                      3)
        W = jax.random.normal(kw, (d_out, d_in), dtype)
        A = jax.random.normal(ka, (r, d_in), dtype) * 0.02
        B = jax.random.normal(kb, (d_out, r), dtype) * 0.02
        times = {}
        for name, fn in {
            "peft_eye": functools.partial(N.norm_peft_eye, s=S),
            "dense_ba": functools.partial(N.norm_dense_ba, s=S),
            "factored": functools.partial(N.factored_norm, s=S,
                                          chunk_mb=256),
        }.items():
            times[name] = time_fn(jax.jit(fn), W, A, B,
                                  repeats=3, warmup=1)["median_s"]
        gap = times["peft_eye"] - times["factored"]
        pos = ((times["peft_eye"] - times["dense_ba"]) / gap
               if abs(gap) > 1e-12 else 0.0)
        row = {"shape": f"{d_out}x{d_in}", "rank": r, **times,
               "dense_ba_position": pos}
        rows.append(row)
        if verbose:
            print(f"  {row['shape']:>12}: peft {times['peft_eye']*1e3:7.1f}ms"
                  f"  dense {times['dense_ba']*1e3:7.1f}ms  factored "
                  f"{times['factored']*1e3:7.1f}ms  -> position "
                  f"{100 * pos:5.1f}%")
    save("dense_ba", rows)
    return rows


def main() -> None:
    print("# Dense-BA position in the PEFT->factored gap (paper Fig 5), "
          "fp32")
    run()


if __name__ == "__main__":
    main()
