"""Model-level benchmark — paper Tables 4/5/8 (gradient computation +
inference across norm configurations).

CPU analogue of the paper's 8-32B three-GPU table: a real (reduced-depth,
real-width) transformer fine-tuned with DoRA under the four configurations
the paper compares — PEFT identity-matrix norm, dense B@A norm, our
factored norm (eager compose), and the factored norm with the fused-kernel
dispatch (Pallas interpret validates the same code path; its wall time is
NOT comparable and is reported separately).

Reported per config: wall s/step (train + inference), compiled HLO
bytes-accessed and temp allocation — the latter two transfer to TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_bytes, save, time_fn
from repro.compat import xla as cxla
from repro.core import DoRAConfig
from repro.launch.steps import StepConfig, make_train_step
from repro.models import init_adapters, init_params, forward
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig, adamw_init

# Reduced-depth / real-width bench model: wide enough that the norm's
# dense materialization is the dominant per-module cost, shallow enough
# to iterate on one CPU core.
BENCH_MCFG = ModelConfig(
    name="bench-1b-slice", family="dense",
    num_layers=2, d_model=1024, num_heads=8, num_kv_heads=4,
    d_ff=2816, vocab_size=4096, dtype=jnp.float32, remat="none")

CONFIGS = {
    "peft_eye": DoRAConfig(rank=384, alpha=192.0, mode="eager",
                           norm_impl="peft_eye"),
    "dense_ba": DoRAConfig(rank=384, alpha=192.0, mode="eager",
                           norm_impl="dense_ba"),
    "eager": DoRAConfig(rank=384, alpha=192.0, mode="eager",
                        norm_impl="factored"),
}

BATCH, SEQ = 2, 256


def _setup(dcfg: DoRAConfig):
    key = jax.random.PRNGKey(0)
    params = init_params(key, BENCH_MCFG)
    adapters = init_adapters(jax.random.fold_in(key, 1), BENCH_MCFG,
                             params, dcfg)
    opt = adamw_init(adapters)
    return params, adapters, opt


def run(verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (BATCH, SEQ), 0,
                                BENCH_MCFG.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (BATCH, SEQ),
                                0, BENCH_MCFG.vocab_size)
    batch = {"tokens": tokens, "labels": labels}

    out = {}
    for name, dcfg in CONFIGS.items():
        scfg = StepConfig(dora=dcfg, optim=OptimizerConfig())
        params, adapters, opt = _setup(dcfg)
        step = jax.jit(make_train_step(BENCH_MCFG, scfg, None,
                                       batch=BATCH, seq=SEQ))
        t_train = time_fn(step, params, adapters, opt, batch,
                          repeats=3, warmup=1)

        fwd = jax.jit(lambda p, a, t: forward(
            BENCH_MCFG, p, a, dcfg, tokens=t, training=False)[0])
        t_inf = time_fn(fwd, params, adapters, tokens, repeats=3, warmup=1)

        lowered = jax.jit(make_train_step(BENCH_MCFG, scfg, None,
                                          batch=BATCH, seq=SEQ)) \
            .lower(params, adapters, opt, batch)
        compiled = lowered.compile()
        cost = cxla.cost_analysis_dict(compiled)
        mem = compiled.memory_analysis()
        out[name] = {
            "train_s": t_train["median_s"],
            "infer_s": t_inf["median_s"],
            "hlo_bytes": cost.get("bytes accessed", 0.0),
            "hlo_flops": cost.get("flops", 0.0),
            "temp_bytes": mem.temp_size_in_bytes,
        }
        if verbose:
            print(f"  {name:>9}: train {out[name]['train_s']:7.3f} s/step"
                  f" | infer {out[name]['infer_s']:7.3f} s | HLO "
                  f"{fmt_bytes(out[name]['hlo_bytes']):>8} | temp "
                  f"{fmt_bytes(out[name]['temp_bytes']):>8}")

    for name in ("dense_ba", "eager"):
        out[name]["train_speedup_vs_peft"] = (out["peft_eye"]["train_s"]
                                              / out[name]["train_s"])
        out[name]["infer_speedup_vs_peft"] = (out["peft_eye"]["infer_s"]
                                              / out[name]["infer_s"])
    if verbose:
        print(f"  speedup vs PEFT: train {out['eager']['train_speedup_vs_peft']:.2f}x"
              f" | infer {out['eager']['infer_speedup_vs_peft']:.2f}x"
              f" | dense-BA train {out['dense_ba']['train_speedup_vs_peft']:.2f}x")
    save("model_level", out)
    return out


def main() -> None:
    print(f"# Model-level (paper Tables 4/5/8): {BENCH_MCFG.name}, "
          f"r=384, bs={BATCH}, seq={SEQ}")
    run()


if __name__ == "__main__":
    main()
