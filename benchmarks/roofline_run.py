"""Roofline table aggregator — reads results/dryrun/*.json (written by
``python -m repro.launch.dryrun``) and renders the per-(arch x shape)
roofline table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load(mesh: str = "16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh:
            rows.append(rec)
    return rows


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful (6ND/HLO) | peak GiB | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        mem = r["memory"]
        uf = r.get("useful_fraction")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | "
            f"{uf if uf is None else round(uf, 3)} | "
            f"{(mem['peak_bytes'] + mem['argument_bytes'] - mem.get('alias_bytes', 0)) / 2**30:.2f} | "
            f"{'Y' if mem['fits_16g'] else 'N'} |")
    return "\n".join(lines)


def main() -> None:
    rows = load("16x16")
    if not rows:
        print(f"# Roofline: no dry-run records in {DRYRUN_DIR} — run "
              "`python -m repro.launch.dryrun` first")
        return
    print(f"# Roofline baseline ({len(rows)} single-pod cells)")
    print(render_markdown(rows))
    mp = load("2x16x16")
    print(f"\n# Multi-pod cells compiled: {len(mp)}")
    save("roofline_table", {"single_pod": rows, "multi_pod": mp})


if __name__ == "__main__":
    main()
